"""Hand-rolled collectives for overlap + compression (shard_map building
blocks the framework's distributed-optimization tricks ride on).

* ring all-gather / reduce-scatter via ``ppermute`` — the overlappable form
  (each hop can interleave with compute inside a scan; XLA schedules hops
  and the consumer's partial work concurrently);
* int8 error-feedback gradient compression: quantize per-block, all-reduce
  the int8 payload (4x less link traffic), accumulate the quantization error
  locally and add it back next step (Seide et al. / 1-bit-Adam style EF).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.parallel.compat import axis_size, shard_map


# ---------------------------------------------------------------------------
# ring primitives (run INSIDE shard_map over the given axis)
# ---------------------------------------------------------------------------
def ring_all_gather(x, axis_name: str):
    """x [s, ...] local shard -> [n*s, ...] via n-1 ppermute hops."""
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def hop(carry, _):
        block, out, k = carry
        block = jax.lax.ppermute(block, axis_name, perm)
        src = (idx - k - 1) % n
        out = jax.lax.dynamic_update_slice_in_dim(
            out, block, src * x.shape[0], axis=0)
        return (block, out, k + 1), None

    out0 = jnp.zeros((n * x.shape[0],) + x.shape[1:], x.dtype)
    out0 = jax.lax.dynamic_update_slice_in_dim(out0, x, idx * x.shape[0], 0)
    (_, out, _), _ = jax.lax.scan(hop, (x, out0, jnp.int32(0)), None, length=n - 1)
    return out


def ring_reduce_scatter(x, axis_name: str):
    """x [n*s, ...] full -> local reduced shard [s, ...] via n-1 hops.

    Device i starts with its contribution to shard (i-1)%n; each hop forwards
    the partial one step around the ring, and the receiver adds its own
    contribution — after n-1 hops device i holds the fully-reduced shard i.
    """
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    s = x.shape[0] // n
    perm = [(i, (i + 1) % n) for i in range(n)]

    def body(carry, k):
        acc = jax.lax.ppermute(carry, axis_name, perm)
        src = (idx - k - 1) % n
        mine = jax.lax.dynamic_slice_in_dim(x, src * s, s, axis=0)
        return acc + mine, None

    start = jax.lax.dynamic_slice_in_dim(x, ((idx - 1) % n) * s, s, axis=0)
    acc, _ = jax.lax.scan(body, start, jnp.arange(1, n))
    return acc


# ---------------------------------------------------------------------------
# int8 error-feedback compressed all-reduce
# ---------------------------------------------------------------------------
def _quantize_int8(x, block: int = 256):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), pad


def _dequantize_int8(q, scale, pad, shape, dtype):
    out = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(shape).astype(dtype)


def compressed_psum(x, axis_name: str, block: int = 256):
    """int8-quantized psum of x over ``axis_name`` (inside shard_map)."""
    q, scale, pad = _quantize_int8(x, block)
    # sum int8 payloads in int32 (bandwidth: 1B/el on the wire under ring RS+AG)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    ssum = jax.lax.psum(scale, axis_name)                 # cheap [nblk, 1]
    n = axis_size(axis_name)
    avg_scale = ssum / n
    return _dequantize_int8(qsum, avg_scale, pad, x.shape, x.dtype)


def make_ef_compressor(params_like: Any, mesh: Mesh, axis: str = "data",
                       block: int = 256):
    """Returns (compress_fn, init_error) implementing error-feedback int8
    gradient all-mean over the data axis.

    compress_fn(grads, err) -> (reduced grads, new err); the quantization
    residual is carried and re-added next step, so the compression bias
    vanishes over time (EF-SGD guarantee).
    """
    def one(g, e, spec):
        def inner(g_, e_):
            corrected = g_.astype(jnp.float32) + e_
            q, scale, pad = _quantize_int8(corrected, block)
            local_deq = _dequantize_int8(q, scale, pad, g_.shape, jnp.float32)
            new_err = corrected - local_deq
            qsum = jax.lax.psum(q.astype(jnp.int32), axis)
            ssum = jax.lax.psum(scale, axis)
            n = axis_size(axis)
            red = _dequantize_int8(qsum, ssum / n, pad, g_.shape, jnp.float32) / n
            return red.astype(g_.dtype), new_err

        return shard_map(inner, mesh=mesh, in_specs=(spec, spec),
                         out_specs=(spec, spec))(g, e)

    def init_error(grads):
        return jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    return one, init_error
