from repro.parallel.sharding import (  # noqa: F401
    DEFAULT_RULES, make_shardings, resolve_spec, with_logical_constraint,
)
