"""Distributed flash-decode: KV cache sharded along sequence, partial
softmax per shard, exact logsumexp combine (the long_500k serving pattern).

Each device holds a contiguous KV slice and computes a local
(m_i, l_i, o_i); the exact global softmax is reconstructed with

    m  = max_i m_i
    l  = sum_i l_i * exp(m_i - m)
    o  = sum_i o_i * l_i * exp(m_i - m) / l

— one psum of [B, H, 1] scalars + one of [B, 1, H, D] vectors per step,
instead of gathering a 500k-token cache.  Runs inside shard_map over the
axis that shards the cache sequence.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from repro.parallel.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _local_partial(q, k, v, valid_len, shard_offset, scale):
    """q [B,1,H,D]; k,v local [B,Sl,Hkv,D]; returns (o, l, m) per head."""
    b, _, h, d = q.shape
    sl, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, 1, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale            # [B,hkv,g,1,Sl]
    kpos = shard_offset + jnp.arange(sl)
    keep = kpos[None, :] < valid_len[:, None]                # [B,Sl]
    s = jnp.where(keep[:, None, None, None, :], s, -1e30)
    m = s.max(-1)                                            # [B,hkv,g,1]
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o, l, m


def dist_decode_attention(q, k, v, valid_len, mesh: Mesh, *,
                          seq_axis: str = "data"):
    """q [B,1,H,D] (replicated over seq_axis); k, v [B,Skv,Hkv,D] sharded on
    dim 1 over ``seq_axis``; valid_len [B]. Returns [B,1,H,D] exact."""
    b, _, h, d = q.shape
    hkv = k.shape[2]
    scale = 1.0 / math.sqrt(d)
    n = mesh.shape[seq_axis]
    s_local = k.shape[1] // n

    def body(q_, k_, v_, vl_):
        idx = jax.lax.axis_index(seq_axis)
        o, l, m = _local_partial(q_, k_, v_, vl_, idx * s_local, scale)
        m_g = jax.lax.pmax(m, seq_axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, seq_axis)
        o_g = jax.lax.psum(o * corr[..., None], seq_axis)
        out = o_g / jnp.maximum(l_g, 1e-30)[..., None]       # [B,hkv,g,1,D]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, 1, h, d).astype(q_.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(None, seq_axis), P(None, seq_axis), P()),
        out_specs=P(), check_vma=False,
    )(q, k, v, valid_len)
