"""jax version compatibility for the distributed layer.

The repo targets current jax (`jax.shard_map`, `check_vma`, mesh
``axis_types``); older releases (e.g. 0.4.x, where these live under
``jax.experimental.shard_map`` as ``check_rep`` and ``make_mesh`` has no
``axis_types``) are supported through these two wrappers.  All repo code and
tests go through them instead of calling jax directly.
"""
from __future__ import annotations

import jax

try:
    from jax import shard_map as _shard_map
    _CHECK_KW = "check_vma"
except ImportError:                      # jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})


def axis_size(axis_name):
    """jax.lax.axis_size fallback: psum of 1 over the axis, which resolves
    to a static int inside shard_map on old jax too."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where the arg exists."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)
