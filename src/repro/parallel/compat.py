"""jax version compatibility for the distributed + kernel layers.

The repo targets current jax (`jax.shard_map`, `check_vma`, mesh
``axis_types``, ``pltpu.CompilerParams``); older releases (e.g. 0.4.x,
where shard_map lives under ``jax.experimental.shard_map`` with
``check_rep``, ``make_mesh`` has no ``axis_types``, and the compiler params
dataclass is ``TPUCompilerParams``) are supported through these wrappers.
All repo code and tests go through them instead of calling jax directly —
CI validates both branches via its jax version matrix.
"""
from __future__ import annotations

import jax

try:
    from jax import shard_map as _shard_map
    _CHECK_KW = "check_vma"
except ImportError:                      # jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})


def axis_size(axis_name):
    """jax.lax.axis_size fallback: psum of 1 over the axis, which resolves
    to a static int inside shard_map on old jax too."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where the arg exists."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def mesh_num_devices(mesh) -> int:
    """Total device count of a mesh (``mesh.size`` on every supported jax;
    kept here so sharding callers have a single seam if the Mesh API drifts)."""
    return int(mesh.size)


def mesh_from_devices(devices, axis: str = "batch"):
    """1-D mesh over an explicit device list (elastic shrink: survivors only).

    ``make_mesh`` always spans the default device order; after a host loss the
    new world is an arbitrary subset, so the Mesh is built directly."""
    import numpy as np
    devs = np.asarray(list(devices), dtype=object)
    if hasattr(jax.sharding, "AxisType"):
        try:
            return jax.sharding.Mesh(devs, (axis,),
                                     axis_types=(jax.sharding.AxisType.Auto,))
        except TypeError:                # older signature without axis_types
            pass
    return jax.sharding.Mesh(devs, (axis,))


def process_count() -> int:
    """Number of jax processes in the job (1 unless jax.distributed ran)."""
    return int(jax.process_count())


def mesh_is_multihost(mesh) -> bool:
    """True when ``mesh`` spans devices owned by more than one process."""
    return len({d.process_index for d in mesh.devices.flat}) > 1


def global_batch_put(x, sharding):
    """Place a host value onto a (possibly multi-host) batch sharding.

    Single-host this is ``jax.device_put``.  Multi-host, every process holds
    the SAME full value (the sharded-search inputs are deterministic
    functions of arguments every process passes identically), and each
    contributes its addressable shards via ``make_array_from_callback`` —
    no cross-process transfer.  Works for typed prng key arrays too."""
    if not mesh_is_multihost(sharding.mesh):
        return jax.device_put(x, sharding)
    return jax.make_array_from_callback(x.shape, sharding,
                                        lambda idx: x[idx])


def replicate_to_hosts(tree, mesh):
    """All-gather a batch-sharded result pytree so every process holds the
    full value (fully-replicated arrays are addressable everywhere).  The one
    cross-process collective of the sharded-search path."""
    rep = replicated_sharding(mesh)
    return jax.jit(lambda t: t, out_shardings=rep)(tree)


def init_distributed_cpu(coordinator: str, num_processes: int,
                         process_id: int) -> None:
    """``jax.distributed.initialize`` for multi-process CPU runs.

    XLA:CPU only executes multi-process programs with the gloo collectives
    backend; the config flag must be set before the backend initializes, so
    this must be the first jax call of the process."""
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # noqa: BLE001 — flag absent: backend defaults suffice
        pass
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)


def batch_sharding(mesh, axis=None):
    """NamedSharding that splits leading array axes over ``axis`` (default:
    the mesh's first axis name).  The one place the sharding-construction API
    is touched, mirroring ``shard_map``/``make_mesh`` above."""
    if axis is None:
        axis = mesh.axis_names[0]
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(axis))


def replicated_sharding(mesh):
    """NamedSharding replicating a value on every device of ``mesh``."""
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())


def tpu_compiler_params(*, dimension_semantics):
    """Pallas TPU compiler params across the rename: current jax exposes
    ``pltpu.CompilerParams``, 0.4.x the same dataclass as
    ``pltpu.TPUCompilerParams``."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(dimension_semantics=dimension_semantics)
