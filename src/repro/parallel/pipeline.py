"""Microbatch pipeline parallelism over a mesh axis (the paper's pattern,
promoted to the model layer — see DESIGN.md §2 table).

GPipe-style schedule via ``shard_map`` + ``ppermute``: the layer stack is
split into S contiguous stages laid out along the ``stage`` mesh axis; M
microbatches stream through with the classic fill/drain bubble of
(S-1)/(M+S-1) — the same arithmetic as the paper's Fig. 3 (7T for 4 items
through 4 stages).

This module implements the *forward* pipeline (inference / evaluation) and a
loss pipeline with recomputation-based backward, exposed as a drop-in for
``hidden_states`` of dense-family models.  It is exercised by tests at smoke
scale and available to the dry-run via ``--pipeline`` (pod axis = stage axis).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.parallel.compat import shard_map


def pipeline_forward(block_fn: Callable, params_stacked: Any, x, mesh: Mesh,
                     *, stage_axis: str = "stage", n_micro: int = None):
    """Run x through L layers laid out as S pipeline stages.

    block_fn(layer_params, x) -> x; params_stacked has leading layer dim L,
    L % S == 0 (layers_per_stage = L // S).  x [B, ...] with B % n_micro == 0.

    Returns block-identical output to running the layers sequentially.
    """
    s = mesh.shape[stage_axis]
    n_micro = n_micro or s
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro

    lead = jax.tree_util.tree_leaves(params_stacked)[0].shape[0]
    assert lead % s == 0, (lead, s)
    per_stage = lead // s

    # reshape params: [L, ...] -> [S, per_stage, ...] sharded over stage
    p_staged = jax.tree_util.tree_map(
        lambda a: a.reshape((s, per_stage) + a.shape[1:]), params_stacked)
    p_specs = jax.tree_util.tree_map(
        lambda a: P(stage_axis, *([None] * (a.ndim - 1))), p_staged)

    def stage_body(p_local, x_all):
        """Runs on ONE stage (shard_map over stage axis)."""
        sid = jax.lax.axis_index(stage_axis)
        micro = x_all.reshape((n_micro, mb) + x_all.shape[1:])

        def run_stage(xmb):
            def layer(carry, lp):
                return block_fn(lp, carry), None
            out, _ = jax.lax.scan(
                layer, xmb, jax.tree_util.tree_map(lambda a: a[0], p_local))
            return out

        n_ticks = n_micro + s - 1
        perm = [(i, (i + 1) % s) for i in range(s)]

        def tick(carry, t):
            buf, done = carry
            # select the microbatch entering stage 0 at tick t
            incoming = jnp.where(
                (t < n_micro),
                micro[jnp.minimum(t, n_micro - 1)], jnp.zeros_like(micro[0]))
            # stage 0 consumes incoming; others consume the permuted buffer
            x_in = jnp.where(sid == 0, incoming, buf)
            y = run_stage(x_in)
            # the LAST stage's output at tick t is microbatch t-(s-1)
            out_idx = t - (s - 1)
            done = jnp.where(
                (sid == s - 1) & (out_idx >= 0),
                done.at[jnp.maximum(out_idx, 0)].set(y), done)
            buf = jax.lax.ppermute(y, stage_axis, perm)
            return (buf, done), None

        buf0 = jnp.zeros_like(micro[0])
        done0 = jnp.zeros_like(micro)
        (_, done), _ = jax.lax.scan(tick, (buf0, done0), jnp.arange(n_ticks))
        # broadcast final outputs (only the last stage holds non-zeros)
        done = jax.lax.psum(jnp.where(sid == s - 1, done, 0), stage_axis)
        return done.reshape((b,) + x.shape[1:])

    out = shard_map(
        stage_body, mesh=mesh,
        in_specs=(p_specs, P()), out_specs=P(),
        check_vma=False,
    )(p_staged, x)
    return out


def pipeline_bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble = (S-1)/(M+S-1) — the paper's fill/drain arithmetic."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
