"""Explicit expert parallelism via shard_map (the D-series follow-up).

Plain-SPMD MoE dispatch cannot shard the experts axis: the data-dependent
gather/scatter across a sharded experts dim lowers to whole-buffer
all-reduces (EXPERIMENTS §Perf D1). This module expresses EP explicitly:

* tokens replicated across the ``expert_axis`` (they already are — the model
  axis carries TP, activations are replicated over it);
* each shard owns E/n experts, locally dispatches ALL tokens to ITS experts
  (top-k hits for other shards' experts simply mask out locally);
* each shard computes partial combine outputs for its experts only;
* one psum over the expert axis sums the partials — the only collective,
  [tokens, D] per MoE layer (same size as a TP matmul reduction), instead of
  [G, E, C, D] buffer all-reduces.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
from repro.parallel.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _local_moe(x2d, router, wg, wu, wd, *, topk: int, n_local: int,
               e_total: int, capacity: int, axis: str):
    """x2d [N, D] (replicated over axis); wg/wu/wd local [E/n, D, F]."""
    idx = jax.lax.axis_index(axis)
    lo = idx * n_local
    gates = jax.nn.softmax((x2d.astype(jnp.float32) @ router), axis=-1)
    topv, topi = jax.lax.top_k(gates, topk)                     # [N, K]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    n, d = x2d.shape
    # global rank within each local expert across ALL top-k slots
    # (token-major flattening: slot (t, j) -> row t*K + j)
    e_all = topi.reshape(-1)                                    # [N*K]
    local_all = (e_all >= lo) & (e_all < lo + n_local)
    le_all = jnp.where(local_all, e_all - lo, n_local)
    onehot = jax.nn.one_hot(le_all, n_local + 1, dtype=jnp.int32)[:, :n_local]
    ranks = jnp.take_along_axis(jnp.cumsum(onehot, 0) - onehot,
                                jnp.minimum(le_all, n_local - 1)[:, None],
                                1)[:, 0]                        # [N*K]

    buf = jnp.zeros((n_local, capacity, d), x2d.dtype)
    for j in range(topk):
        le_j, pos_j, loc_j = le_all[j::topk], ranks[j::topk], local_all[j::topk]
        pos_j = jnp.where(loc_j & (pos_j < capacity), pos_j, capacity)
        buf = buf.at[jnp.minimum(le_j, n_local - 1), pos_j].add(
            x2d, mode="drop")
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg,
                               preferred_element_type=jnp.float32))
    h = h.astype(x2d.dtype) * jnp.einsum("ecd,edf->ecf", buf, wu)
    yb = jnp.einsum("ecf,efd->ecd", h, wd)                      # [E/n, C, D]

    y = jnp.zeros((n, d), jnp.float32)
    for j in range(topk):
        le_j, pos_j, loc_j = le_all[j::topk], ranks[j::topk], local_all[j::topk]
        got = yb[jnp.minimum(le_j, n_local - 1), jnp.minimum(pos_j, capacity - 1)]
        keep = (loc_j & (pos_j < capacity))[:, None]
        y = y + jnp.where(keep, got, 0).astype(jnp.float32) * topv[:, j][:, None]
    return jax.lax.psum(y, axis).astype(x2d.dtype)


def ep_moe_ffn(x2d, params: Dict[str, Any], mesh: Mesh, *, topk: int,
               capacity_factor: float = 1.25, expert_axis: str = "model"):
    """x2d [N, D] (token rows sharded over the data axes, replicated over
    ``expert_axis``); params {router [D,E], wg/wu/wd [E, D, F]/[E, F, D]}.

    Each (data_i, expert_j) device dispatches its LOCAL token shard to its
    LOCAL experts; one psum over ``expert_axis`` combines. Exact match with
    the plain-SPMD dispatch at equal capacity (see tests).
    """
    e_total = params["wg"].shape[0]
    n_shards = mesh.shape[expert_axis]
    assert e_total % n_shards == 0, (e_total, n_shards)
    n_local = e_total // n_shards
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names
                      and x2d.shape[0] % mesh.shape[a] == 0)
    n_data = math.prod(mesh.shape[a] for a in data_axes) if data_axes else 1
    n_tok_local = x2d.shape[0] // n_data
    capacity = max(8, int(math.ceil(
        capacity_factor * n_tok_local * topk / e_total)))
    tok_spec = P(data_axes if data_axes else None)

    body = lambda x, r, g, u, w: _local_moe(
        x, r, g, u, w, topk=topk, n_local=n_local, e_total=e_total,
        capacity=capacity, axis=expert_axis)
    return shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, P(), P(expert_axis), P(expert_axis), P(expert_axis)),
        out_specs=tok_spec, check_vma=False,
    )(x2d, params["router"], params["wg"], params["wu"], params["wd"])
