"""Logical-axis sharding rules with divisibility-aware fallback.

Every param/cache tensor carries a tuple of logical axis names (see each
family's ``param_axes`` / ``cache_axes``).  A rules table maps logical axes to
candidate mesh axes *in priority order*; resolution walks each tensor's dims,
assigning the first candidate mesh axis (or axis tuple) that (a) is still
unused by this tensor and (b) divides the dim size.  Indivisible dims fall
back to replication — e.g. smollm's 9 heads on a 16-way model axis — instead
of failing, which is what lets one rules table drive all 10 architectures.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> candidate mesh-axis assignments, best first.
# each candidate is a tuple of mesh axes used together for that dim.
DEFAULT_RULES: Dict[str, Tuple[Tuple[str, ...], ...]] = {
    "batch":   (("pod", "data"), ("data",)),
    "vocab":   (("model",),),
    "embed":   (("data",),),          # FSDP / ZeRO-3 storage sharding
    "heads":   (("model",),),
    "kv":      (("model",),),
    "mlp":     (("model",),),
    # experts stay replicated under plain-SPMD dispatch: sharding the experts
    # axis makes the (data-dependent) dispatch gather/scatter cross-shard and
    # XLA lowers it to per-layer all-reduces of the whole [G,E,C,D] buffer
    # (measured: 17 TB/device/step on deepseek train_4k — see EXPERIMENTS §Perf
    # iter D1). TP-within-expert (embed->data, mlp->model) carries the weight
    # sharding instead; true EP needs the shard_map dispatch (future work).
    "experts": (),
    # decode/long cells: shard the KV-cache sequence axis over whatever is
    # left after batch/kv-heads claim their axes (flash-decode split-K across
    # devices; combined via XLA's partitioned softmax).
    "kv_seq":  (("model", "data"), ("model",), ("data",)),
    "layers":  (),
    "seq":     (),
    # saved layer-boundary activations (remat carries) shard their seq dim
    # over the model axis (Megatron sequence parallelism)
    "act_seq": (("model",),),
}


def _mesh_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_spec(axes: Optional[Tuple], shape: Tuple[int, ...], mesh: Mesh,
                 rules: Dict[str, Tuple] = None) -> P:
    """(logical axes, shape) -> PartitionSpec under the rules table."""
    rules = rules or DEFAULT_RULES
    sizes = _mesh_sizes(mesh)
    if axes is None:
        return P()
    assert len(axes) == len(shape), (axes, shape)
    used: set = set()
    parts = []
    for name, dim in zip(axes, shape):
        assigned = None
        if name is not None:
            for cand in rules.get(name, ()):
                cand = tuple(a for a in cand if a in sizes)
                if not cand or any(a in used for a in cand):
                    continue
                total = math.prod(sizes[a] for a in cand)
                if total > 1 and dim % total == 0:
                    assigned = cand if len(cand) > 1 else cand[0]
                    used.update(cand)
                    break
        parts.append(assigned)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def make_shardings(axes_tree: Any, abstract_tree: Any, mesh: Mesh,
                   rules: Dict[str, Tuple] = None) -> Any:
    """Pytree of NamedSharding matching ``abstract_tree``'s structure."""
    is_axes = lambda x: x is None or (isinstance(x, tuple)
                                      and all(a is None or isinstance(a, str) for a in x))

    def one(ax, leaf):
        spec = resolve_spec(ax if ax is not None else (None,) * leaf.ndim,
                            leaf.shape, mesh, rules)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(one, axes_tree, abstract_tree, is_leaf=is_axes)


def spec_tree(axes_tree: Any, abstract_tree: Any, mesh: Mesh,
              rules: Dict[str, Tuple] = None) -> Any:
    is_axes = lambda x: x is None or (isinstance(x, tuple)
                                      and all(a is None or isinstance(a, str) for a in x))
    return jax.tree_util.tree_map(
        lambda ax, leaf: resolve_spec(ax if ax is not None else (None,) * leaf.ndim,
                                      leaf.shape, mesh, rules),
        axes_tree, abstract_tree, is_leaf=is_axes)


_ACTIVE_RULES: contextvars.ContextVar = contextvars.ContextVar(
    "repro_active_rules", default=None)


@contextlib.contextmanager
def active_rules(rules: Dict[str, Tuple]):
    """Make per-arch rule overrides visible to every logical constraint
    traced within (the dry-run wraps lowering in this)."""
    tok = _ACTIVE_RULES.set(rules)
    try:
        yield
    finally:
        _ACTIVE_RULES.reset(tok)


def with_logical_constraint(x, axes: Tuple, mesh: Optional[Mesh] = None,
                            rules: Dict[str, Tuple] = None):
    """with_sharding_constraint via logical axis names (no-op off-mesh)."""
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    rules = rules or _ACTIVE_RULES.get() or DEFAULT_RULES
    spec = resolve_spec(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh() -> Optional[Mesh]:
    try:
        from jax._src.mesh import thread_resources
        env = thread_resources.env
        return env.physical_mesh
    except Exception:
        return None
