"""Request lifecycle scheduling for the serving engine (DESIGN.md §12).

The paper's pipeline keeps every *stage* busy with useful work; this module
is the same idea one layer up — an explicit task structure the serving loop
schedules against, instead of ad-hoc slot bookkeeping inside the engine.
``RequestScheduler`` owns the admission queue and the per-slot state
machine; the engine owns device state (KV rows, prefix buffers, search
carry) and reacts to the scheduler's events.

State machine per slot::

    free --admit--> live --retire--> free     (finished: budget / EOS / capacity)
                      '--evict--> requeued    (preempted by higher priority,
                                               or forced by an elastic shrink;
                                               ``disable`` then retires the
                                               slot from the pool for good)

* **Admission policy** (``policy=``): ``"fcfs"`` admits in arrival order,
  ``"spf"`` shortest-prompt-first (by *effective* prefix — prompt plus
  committed tokens — so requeued requests are ordered by real prefill
  cost).  Both order by ``Request.priority`` first (higher wins).
* **Preemption**: when every slot is live and a queued request has strictly
  higher priority than the lowest-priority live request, that victim is
  evicted and requeued *with its committed tokens intact* — on readmission
  its prompt + ``out_tokens`` become the prefix and only the remaining
  budget is decoded.  FCFS position is preserved across eviction (the
  request keeps its original arrival sequence number).
* **Budgets**: per-slot ``remaining`` decode budget, derived from
  ``max_new_tokens`` minus committed tokens at admission; the engine may
  clamp it further (KV/sequence capacity) via ``cap_remaining``.

``schedule()`` performs every admission/eviction possible right now and
returns the ordered event list; it is safe to call at any point (idempotent
when nothing can move), which is what lets the engine refill a slot in the
same engine step that freed it (EOS mid-budget, DESIGN.md §12).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

POLICIES = ("fcfs", "spf")


@dataclasses.dataclass
class Request:
    """One decode request.  ``priority`` orders admission and drives
    preemption (higher = more important; default 0).  ``enqueue_t`` /
    ``finish_t`` are populated by the engine from its stats clock."""
    uid: int
    prompt: np.ndarray                 # [len] int32
    max_new_tokens: int = 16
    priority: int = 0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    enqueue_t: float = 0.0
    finish_t: float = 0.0

    @property
    def prefix_len(self) -> int:
        """Effective prefix: prompt plus already-committed tokens."""
        return len(self.prompt) + len(self.out_tokens)

    @property
    def budget_left(self) -> int:
        return self.max_new_tokens - len(self.out_tokens)


@dataclasses.dataclass(frozen=True)
class Admit:
    slot: int
    req: Request


@dataclasses.dataclass(frozen=True)
class Evict:
    slot: int
    req: Request


class RequestScheduler:
    """Admission queue + per-slot request state machine."""

    def __init__(self, num_slots: int, policy: str = "fcfs"):
        if policy not in POLICIES:
            raise ValueError(
                f"unknown admission policy {policy!r}; one of {POLICIES}")
        if num_slots < 1:
            raise ValueError(f"need at least one slot, got {num_slots}")
        self.policy = policy
        self.num_slots = num_slots
        # slot keeps its last request after retire (engine/tests inspect it);
        # _live is the authoritative occupancy bit
        self._slots: List[Optional[Request]] = [None] * num_slots
        self._live = [False] * num_slots
        self._disabled = [False] * num_slots
        self.remaining = np.zeros(num_slots, np.int64)
        self._queue: List[Request] = []
        self._seq = 0
        self._seq_of = {}                  # uid -> arrival sequence number

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.uid not in self._seq_of:    # evictions keep their FCFS spot
            self._seq_of[req.uid] = self._seq
            self._seq += 1
        self._queue.append(req)

    def pending(self) -> int:
        return len(self._queue)

    def _order_key(self, req: Request):
        if self.policy == "spf":
            return (-req.priority, req.prefix_len, self._seq_of[req.uid])
        return (-req.priority, self._seq_of[req.uid])

    # -- slot views ---------------------------------------------------------
    @property
    def slots(self) -> List[Optional[Request]]:
        """Last request seen by each slot (live or just-finished)."""
        return list(self._slots)

    def request(self, slot: int) -> Optional[Request]:
        return self._slots[slot]

    def is_live(self, slot: int) -> bool:
        return self._live[slot]

    def live(self) -> List[int]:
        return [i for i in range(self.num_slots) if self._live[i]]

    # -- budgets ------------------------------------------------------------
    def cap_remaining(self, slot: int, n: int) -> None:
        self.remaining[slot] = min(int(self.remaining[slot]), n)

    def on_token(self, slot: int) -> None:
        self.remaining[slot] -= 1

    def exhausted(self, slot: int) -> bool:
        return int(self.remaining[slot]) <= 0

    # -- transitions --------------------------------------------------------
    def retire(self, slot: int) -> None:
        """live -> free.  The request object stays visible in ``slots``."""
        self._live[slot] = False
        self.remaining[slot] = 0

    def evict(self, slot: int) -> Optional[Evict]:
        """Forced eviction of one slot (elastic shrink, DESIGN.md §13):
        live -> requeued with committed tokens and FCFS seq intact — the
        same contract as priority preemption, but driven by the world
        changing instead of by a better candidate.  No-op on a free slot."""
        if not self._live[slot]:
            return None
        victim = self._slots[slot]
        self._live[slot] = False
        self.remaining[slot] = 0
        self._queue.append(victim)
        return Evict(slot, victim)

    def disable(self, slots) -> None:
        """Remove slots from the admission pool (a lost host's slots after a
        shrink).  Disabled slots are never admitted to again; live requests
        on them must be ``evict``-ed by the caller first."""
        for s in slots:
            self._disabled[s] = True

    def num_enabled(self) -> int:
        return sum(not d for d in self._disabled)

    def is_disabled(self, slot: int) -> bool:
        return self._disabled[slot]

    def _victim(self) -> Optional[int]:
        """Lowest-priority live slot; ties broken by least progress (fewest
        committed tokens — cheapest to redo), then slot index."""
        live = self.live()
        if not live:
            return None
        return min(live, key=lambda i: (self._slots[i].priority,
                                        len(self._slots[i].out_tokens), i))

    def schedule(self) -> List[object]:
        """Admit every queued request a slot can be found for, evicting
        strictly-lower-priority live requests when the pool is full.
        Returns the ordered ``Admit``/``Evict`` events performed."""
        events: List[object] = []
        while self._queue:
            qi = min(range(len(self._queue)),
                     key=lambda j: self._order_key(self._queue[j]))
            cand = self._queue[qi]
            slot = next((i for i in range(self.num_slots)
                         if not self._live[i] and not self._disabled[i]),
                        None)
            if slot is None:
                v = self._victim()
                # candidates are ordered priority-first, so if the best one
                # cannot preempt, none can — stop
                if v is None or self._slots[v].priority >= cand.priority:
                    break
                victim = self._slots[v]
                self._live[v] = False
                self.remaining[v] = 0
                self._queue.append(victim)     # committed tokens ride along
                events.append(Evict(v, victim))
                slot = v
            self._queue.pop(qi)
            self._slots[slot] = cand
            self._live[slot] = True
            self.remaining[slot] = cand.budget_left
            events.append(Admit(slot, cand))
        return events
