"""Continuous-batching serving engine (prefill/decode interleave).

Host-side orchestration over the jitted ``prefill``/``decode_step`` of any
arch in the zoo: a fixed pool of ``max_batch`` decode slots; finished or
empty slots are refilled by prefilling queued requests into the batch
position (per-slot KV cache rows + per-slot positions), so decode steps
always run at full batch — the serving-side analogue of keeping the paper's
pipeline stages busy.

Request lifecycle (DESIGN.md §12): admission order, per-slot budgets and
priority preemption live in ``serving.scheduler.RequestScheduler``; the
engine owns device state (KV rows, prefix buffers, search carry) and reacts
to the scheduler's ``Admit``/``Evict`` events.  ``ServingStats`` records
the lifecycle timings (queue wait, TTFT, per-token gaps, latency) and
engine counters; ``run_until_drained`` returns its per-request summaries
and ``ServingEngine.stats.snapshot()`` is a flat wandb-ready dict.

Two per-slot decode modes (EngineConfig.decode):

* ``"greedy"`` — KV-cached argmax decoding (the seed behaviour).
* ``"mcts"``   — every engine step runs ONE batched multi-root search
  (repro.search.search_batch via make_batched_searcher) over all live
  slots' prefixes and commits each slot's chosen token: the paper's search
  as a serving feature, one device program per emitted token across the
  whole batch (DESIGN.md §5).  KV-cache-aware by default
  (``MCTSDecodeConfig.cached``): inside that program each slot gets its own
  cache row, prefilled once per search and shared by every playout of that
  root; with ``EngineConfig.mesh`` the rows shard along the slot axis like
  the prefix buffer (DESIGN.md §10).  With ``MCTSDecodeConfig.kv_splice`` /
  ``tree_reuse`` the searcher is the stateful ``ReusableSearcher`` and the
  engine threads its per-slot carry through admissions and steps: prompts
  prefill once per request lifetime and committed subtrees warm-start the
  next token's search (DESIGN.md §12).  The searches' Select-stage
  iteration order follows ``MCTSDecodeConfig.wave_select`` (lockstep = one
  batched UCT pass per tree level; DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ModelConfig, get_family
from repro.serving.mcts_decode import (MCTSDecodeConfig, ReusableSearcher,
                                       make_batched_searcher)
from repro.serving.scheduler import (Admit, Evict, Request, RequestScheduler)
from repro.serving.stats import ServingStats, percentile


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 4
    max_seq: int = 256
    eos_token: int = -1                # -1: never stops early
    decode: str = "greedy"             # "greedy" | "mcts"
    policy: str = "fcfs"               # admission policy: "fcfs" | "spf"
    mcts: Optional[MCTSDecodeConfig] = None   # knobs for decode="mcts"
    # decode="mcts" device mesh: None auto-shards the per-step batched search
    # across all visible devices (live slots spread over a 1-D mesh, DESIGN.md
    # §9); False pins it to one device; or pass an explicit 1-D mesh.
    mesh: Any = None


class ServingEngine:
    """Single-host continuous batching over jitted model steps."""

    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig,
                 stats: Optional[ServingStats] = None):
        self.cfg = cfg
        self.params = params
        self.ecfg = engine_cfg
        self.fam = get_family(cfg)
        b, s = engine_cfg.max_batch, engine_cfg.max_seq
        self.stats = stats if stats is not None else ServingStats()
        self.sched = RequestScheduler(b, policy=engine_cfg.policy)
        # the persistent [L, B, S, ...] cache backs the greedy path; mcts
        # mode's per-slot cache rows live inside the per-token search
        # program instead (prefilled from prefix_buf, DESIGN.md §10)
        self.cache = (self.fam.init_cache(cfg, b, s)
                      if engine_cfg.decode == "greedy" else None)
        self._decode = jax.jit(
            lambda p, c, t: self.fam.decode_step(cfg, p, c, t))
        self._prefill_one = jax.jit(
            lambda p, t, c: self.fam.prefill(cfg, p, t, c))
        self.mode = engine_cfg.decode
        self._carry = None
        if self.mode == "mcts":
            self.mcfg = engine_cfg.mcts or MCTSDecodeConfig()
            # per-slot padded prefix buffers; true lengths ride separately so
            # the batched searcher keeps one static shape for all steps
            self.prefix_buf = np.zeros((b, s), np.int32)
            self.prefix_len = np.zeros((b,), np.int32)
            self._rng = jax.random.key(0)
            self._mcts_search = make_batched_searcher(
                cfg, params, self.mcfg, batch=b, mesh=engine_cfg.mesh)
            if isinstance(self._mcts_search, ReusableSearcher):
                self._carry = self._mcts_search.init_carry(s)
        elif self.mode != "greedy":
            raise ValueError(f"unknown decode mode {engine_cfg.decode!r}")

    # -- request intake ----------------------------------------------------
    @property
    def slots(self) -> List[Optional[Request]]:
        """Last request seen by each slot (live or just-finished)."""
        return self.sched.slots

    def submit(self, req: Request):
        if len(req.prompt) > self.ecfg.max_seq:
            raise ValueError(
                f"prompt of request {req.uid} has {len(req.prompt)} tokens, "
                f"exceeding max_seq={self.ecfg.max_seq}")
        req.enqueue_t = self.stats.now()
        self.stats.on_submit(req.uid, req.enqueue_t)
        self.sched.submit(req)

    def pending(self) -> int:
        return self.sched.pending()

    # -- scheduler event handlers -------------------------------------------
    def _admit_loop(self):
        """Apply scheduler events until quiescent.  Admissions that finish
        immediately (zero budget, prefill EOS, capacity) retire their slot,
        which can unblock another admission — hence the loop."""
        while True:
            events = self.sched.schedule()
            if not events:
                return
            for ev in events:
                if isinstance(ev, Evict):
                    self._on_evict(ev.slot, ev.req)
                else:
                    self._on_admit(ev.slot, ev.req)

    def _on_evict(self, i: int, req: Request):
        """Eviction contract (DESIGN.md §12): device state is simply dropped
        — the prefix buffer row is zeroed and any searcher carry row goes
        stale (readmission overwrites it via ``admit``).  The request keeps
        its committed tokens; readmission re-prefills prompt + out_tokens."""
        self.stats.on_preempt(req.uid, self.stats.now())
        if self.mode == "mcts":
            self.prefix_buf[i] = 0
            self.prefix_len[i] = 0
        # greedy: the KV row is dead weight until the slot is refilled

    def shrink(self, lost_slots) -> List[int]:
        """Elastic shrink event (DESIGN.md §13): a lost host's slots are
        evicted-and-requeued through the scheduler — victims keep their
        committed tokens and FCFS position, exactly like priority preemption
        — and removed from the admission pool for good.  Surviving slots are
        refilled immediately, so the engine keeps serving at the shrunken
        batch.  Returns the slots that actually held a live request."""
        lost = sorted({int(s) for s in lost_slots})
        newly = [s for s in lost if not self.sched.is_disabled(s)]
        if self.sched.num_enabled() - len(newly) < 1:
            raise ValueError("shrink would disable every slot; at least one "
                             "must survive to keep serving")
        evicted = []
        for s in lost:
            ev = self.sched.evict(s)
            if ev is not None:
                self._on_evict(ev.slot, ev.req)
                evicted.append(s)
        self.sched.disable(lost)
        self._admit_loop()
        return evicted

    def _finish(self, i: int, req: Request):
        req.done = True
        req.finish_t = self.stats.now()
        self.stats.on_finish(req.uid, req.finish_t)
        self.sched.retire(i)

    def _on_admit(self, i: int, req: Request):
        self.stats.on_admit(req.uid, self.stats.now())
        if req.budget_left <= 0:
            # nothing to decode: finish without touching device state
            self._finish(i, req)
            return
        # effective prefix = prompt + committed tokens (preemption round-trip)
        prefix = np.asarray(list(req.prompt) + req.out_tokens, np.int32)
        plen = len(prefix)
        if self.mode == "mcts":
            # no host-side KV prefill on the cold path: the searcher prefills
            # this slot's cache row from the prefix buffer inside each
            # per-token program (zeroing the buffer row is the slot reset).
            # Stateful searchers prefill ONCE here instead (KV splice).
            self.prefix_buf[i] = 0
            self.prefix_buf[i, :plen] = prefix
            self.prefix_len[i] = plen
            if self._carry is not None:
                self._carry = self._mcts_search.admit(
                    self._carry, i, self.prefix_buf[i], plen)
            return
        # greedy: prefill this request alone, splice its cache row into slot i
        one_cache = self.fam.init_cache(self.cfg, 1, self.ecfg.max_seq)
        logits, one_cache = self._prefill_one(
            self.params, jnp.asarray(prefix, jnp.int32)[None], one_cache)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out_tokens.append(tok)
        self.stats.on_token(req.uid, self.stats.now())
        self.sched.on_token(i)
        # each decode step writes one KV entry at position plen, plen+1,
        # ... — clamp so the slot finishes before scattering past max_seq
        self.sched.cap_remaining(i, self.ecfg.max_seq - plen)
        self.cache = jax.tree_util.tree_map(
            lambda full, one: full.at[_batch_axis_index(full, i)].set(
                one[_one_index(one)]),
            self.cache, one_cache)
        if self.sched.exhausted(i) or tok == self.ecfg.eos_token:
            self._finish(i, req)

    def _next_tokens(self) -> jnp.ndarray:
        toks = np.zeros((self.ecfg.max_batch, 1), np.int32)
        for i in self.sched.live():
            req = self.sched.request(i)
            if req.out_tokens:
                toks[i, 0] = req.out_tokens[-1]
        return jnp.asarray(toks)

    # -- main loop ----------------------------------------------------------
    def step(self):
        """One decode step over all live slots.  Slots freed mid-step (EOS,
        budget, capacity) are refilled before returning, so the NEXT step
        already decodes the replacement — no idle step in between."""
        self._admit_loop()
        live = self.sched.live()
        if not live:
            return 0
        if self.mode == "mcts":
            emitted = self._mcts_step(live)
            self.stats.on_step(emitted, searched=len(live))
        else:
            emitted = self._greedy_step(live)
            self.stats.on_step(emitted)
        self._admit_loop()          # refill freed slots in the same step
        return emitted

    def _greedy_step(self, live: List[int]) -> int:
        logits, self.cache = self._decode(self.params, self.cache,
                                          self._next_tokens())
        toks = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        now = self.stats.now()
        for i in live:
            req = self.sched.request(i)
            tok = int(toks[i])
            req.out_tokens.append(tok)
            self.stats.on_token(req.uid, now)
            self.sched.on_token(i)
            if self.sched.exhausted(i) or tok == self.ecfg.eos_token:
                self._finish(i, req)
        return len(live)

    def _mcts_step(self, live: List[int]) -> int:
        """One batched multi-root search over every slot; commit one token
        per live slot.  Dead slots are searched too (the program is one fixed
        [B]-batch) and their outputs ignored."""
        self._rng, sub = jax.random.split(self._rng)
        if self._carry is not None:
            toks, self._carry = self._mcts_search.step(
                self.prefix_buf, self.prefix_len, sub, self._carry)
            toks = np.asarray(toks)
        else:
            toks = np.asarray(self._mcts_search(
                jnp.asarray(self.prefix_buf), jnp.asarray(self.prefix_len),
                sub))
        now = self.stats.now()
        for i in live:
            req = self.sched.request(i)
            tok = int(toks[i])
            req.out_tokens.append(tok)
            self.stats.on_token(req.uid, now)
            at_capacity = self.prefix_len[i] >= self.ecfg.max_seq
            if not at_capacity:
                self.prefix_buf[i, self.prefix_len[i]] = tok
                self.prefix_len[i] += 1
            self.sched.on_token(i)
            # finish at the sequence capacity too — further searches would
            # keep emitting from the same frozen prefix
            if (self.sched.exhausted(i) or tok == self.ecfg.eos_token
                    or at_capacity):
                self._finish(i, req)
        return len(live)

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[str, Any]:
        emitted = 0
        steps = 0
        while steps < max_steps:
            e = self.step()
            steps += 1
            emitted += e
            if e == 0 and self.sched.pending() == 0:
                break
        reqs = self.stats.request_summaries()
        lats = [r["latency"] for r in reqs.values()
                if r["latency"] is not None]
        return {
            "steps": steps,
            "tokens": emitted,
            "requests": reqs,
            "latency_p50": percentile(lats, 50) if lats else 0.0,
            "latency_p95": percentile(lats, 95) if lats else 0.0,
            "stats": self.stats.snapshot(),
        }


def _batch_axis_index(full, i):
    """Index tuple selecting batch row i (batch axis differs per cache leaf)."""
    # conventions: leaves are [L, B, ...] (stacked) or [B] (pos)
    if full.ndim == 1:
        return (i,)
    return (slice(None), i)


def _one_index(one):
    if one.ndim == 1:
        return (0,)
    return (slice(None), 0)
