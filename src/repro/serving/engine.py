"""Continuous-batching serving engine (prefill/decode interleave).

Host-side orchestration over the jitted ``prefill``/``decode_step`` of any
arch in the zoo: a fixed pool of ``max_batch`` decode slots; finished or
empty slots are refilled by prefilling queued requests into the batch
position (per-slot KV cache rows + per-slot positions), so decode steps
always run at full batch — the serving-side analogue of keeping the paper's
pipeline stages busy.

Two per-slot decode modes (EngineConfig.decode):

* ``"greedy"`` — KV-cached argmax decoding (the seed behaviour).
* ``"mcts"``   — every engine step runs ONE batched multi-root search
  (repro.search.search_batch via make_batched_searcher) over all live
  slots' prefixes and commits each slot's chosen token: the paper's search
  as a serving feature, one device program per emitted token across the
  whole batch (DESIGN.md §5).  KV-cache-aware by default
  (``MCTSDecodeConfig.cached``): inside that program each slot gets its own
  cache row, prefilled once per search and shared by every playout of that
  root; with ``EngineConfig.mesh`` the rows shard along the slot axis like
  the prefix buffer (DESIGN.md §10).  The searches' Select-stage iteration
  order follows ``MCTSDecodeConfig.wave_select`` (lockstep = one batched
  UCT pass per tree level; DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ModelConfig, get_family
from repro.serving.mcts_decode import MCTSDecodeConfig, make_batched_searcher


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [len] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    enqueue_t: float = 0.0
    finish_t: float = 0.0


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 4
    max_seq: int = 256
    eos_token: int = -1                # -1: never stops early
    decode: str = "greedy"             # "greedy" | "mcts"
    mcts: Optional[MCTSDecodeConfig] = None   # knobs for decode="mcts"
    # decode="mcts" device mesh: None auto-shards the per-step batched search
    # across all visible devices (live slots spread over a 1-D mesh, DESIGN.md
    # §9); False pins it to one device; or pass an explicit 1-D mesh.
    mesh: Any = None


class ServingEngine:
    """Single-host continuous batching over jitted model steps."""

    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig):
        self.cfg = cfg
        self.params = params
        self.ecfg = engine_cfg
        self.fam = get_family(cfg)
        b, s = engine_cfg.max_batch, engine_cfg.max_seq
        # the persistent [L, B, S, ...] cache backs the greedy path; mcts
        # mode's per-slot cache rows live inside the per-token search
        # program instead (prefilled from prefix_buf, DESIGN.md §10)
        self.cache = (self.fam.init_cache(cfg, b, s)
                      if engine_cfg.decode == "greedy" else None)
        self.slots: List[Optional[Request]] = [None] * b
        self.remaining = np.zeros(b, np.int32)
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self._decode = jax.jit(
            lambda p, c, t: self.fam.decode_step(cfg, p, c, t))
        self._prefill_one = jax.jit(
            lambda p, t, c: self.fam.prefill(cfg, p, t, c))
        self.mode = engine_cfg.decode
        if self.mode == "mcts":
            self.mcfg = engine_cfg.mcts or MCTSDecodeConfig()
            # per-slot padded prefix buffers; true lengths ride separately so
            # the batched searcher keeps one static shape for all steps
            self.prefix_buf = np.zeros((b, s), np.int32)
            self.prefix_len = np.zeros((b,), np.int32)
            self._rng = jax.random.key(0)
            self._mcts_search = make_batched_searcher(
                cfg, params, self.mcfg, batch=b, mesh=engine_cfg.mesh)
        elif self.mode != "greedy":
            raise ValueError(f"unknown decode mode {engine_cfg.decode!r}")

    # -- request intake ----------------------------------------------------
    def submit(self, req: Request):
        if len(req.prompt) > self.ecfg.max_seq:
            raise ValueError(
                f"prompt of request {req.uid} has {len(req.prompt)} tokens, "
                f"exceeding max_seq={self.ecfg.max_seq}")
        req.enqueue_t = time.time()
        self.queue.put(req)

    # -- slot management ---------------------------------------------------
    def _fill_slots(self):
        for i, slot in enumerate(self.slots):
            if slot is not None and not slot.done:
                continue
            try:
                req = self.queue.get_nowait()
            except queue.Empty:
                return
            if req.max_new_tokens <= 0:
                req.done = True
                req.finish_t = time.time()
                self.slots[i] = req
                self.remaining[i] = 0
                continue
            plen = len(req.prompt)
            if self.mode == "mcts":
                # no host-side KV prefill: the searcher prefills this slot's
                # cache row from the prefix buffer inside each per-token
                # program (zeroing the buffer row is the slot reset — no
                # state outlives the request); the first token comes from
                # the first search step
                self.slots[i] = req
                self.remaining[i] = req.max_new_tokens
                self.prefix_buf[i] = 0
                self.prefix_buf[i, :plen] = np.asarray(req.prompt, np.int32)
                self.prefix_len[i] = plen
                continue
            # prefill this request alone, then splice its cache row into slot i
            one_cache = self.fam.init_cache(self.cfg, 1, self.ecfg.max_seq)
            logits, one_cache = self._prefill_one(
                self.params, jnp.asarray(req.prompt, jnp.int32)[None], one_cache)
            tok = int(jnp.argmax(logits[0, -1]))
            req.out_tokens.append(tok)
            self.slots[i] = req
            # each decode step writes one KV entry at position plen, plen+1,
            # ... — clamp so the slot finishes before scattering past max_seq
            self.remaining[i] = min(req.max_new_tokens - 1,
                                    self.ecfg.max_seq - plen)
            if self.remaining[i] <= 0 or tok == self.ecfg.eos_token:
                req.done = True
                req.finish_t = time.time()
            self.cache = jax.tree_util.tree_map(
                lambda full, one: full.at[_batch_axis_index(full, i)].set(one[_one_index(one)]),
                self.cache, one_cache)

    def _next_tokens(self) -> jnp.ndarray:
        toks = np.zeros((self.ecfg.max_batch, 1), np.int32)
        for i, slot in enumerate(self.slots):
            if slot is not None and slot.out_tokens:
                toks[i, 0] = slot.out_tokens[-1]
        return jnp.asarray(toks)

    # -- main loop ----------------------------------------------------------
    def step(self):
        """One decode step over all live slots."""
        self._fill_slots()
        live = [i for i, s in enumerate(self.slots) if s is not None and not s.done]
        if not live:
            return 0
        if self.mode == "mcts":
            return self._mcts_step(live)
        logits, self.cache = self._decode(self.params, self.cache,
                                          self._next_tokens())
        toks = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        emitted = 0
        for i in live:
            req = self.slots[i]
            tok = int(toks[i])
            req.out_tokens.append(tok)
            self.remaining[i] -= 1
            emitted += 1
            if self.remaining[i] <= 0 or tok == self.ecfg.eos_token:
                req.done = True
                req.finish_t = time.time()
        return emitted

    def _mcts_step(self, live: List[int]) -> int:
        """One batched multi-root search over every slot; commit one token
        per live slot.  Dead slots are searched too (the program is one fixed
        [B]-batch) and their outputs ignored."""
        self._rng, sub = jax.random.split(self._rng)
        toks = np.asarray(self._mcts_search(
            jnp.asarray(self.prefix_buf), jnp.asarray(self.prefix_len), sub))
        emitted = 0
        for i in live:
            req = self.slots[i]
            tok = int(toks[i])
            req.out_tokens.append(tok)
            at_capacity = self.prefix_len[i] >= self.ecfg.max_seq
            if not at_capacity:
                self.prefix_buf[i, self.prefix_len[i]] = tok
                self.prefix_len[i] += 1
            self.remaining[i] -= 1
            emitted += 1
            # finish at the sequence capacity too — further searches would
            # keep emitting from the same frozen prefix
            if (self.remaining[i] <= 0 or tok == self.ecfg.eos_token
                    or at_capacity):
                req.done = True
                req.finish_t = time.time()
        return emitted

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[str, Any]:
        emitted = 0
        steps = 0
        while steps < max_steps:
            e = self.step()
            steps += 1
            emitted += e
            if e == 0 and self.queue.empty():
                break
        return {"steps": steps, "tokens": emitted}


def _batch_axis_index(full, i):
    """Index tuple selecting batch row i (batch axis differs per cache leaf)."""
    # conventions: leaves are [L, B, ...] (stacked) or [B] (pos)
    if full.ndim == 1:
        return (i,)
    return (slice(None), i)


def _one_index(one):
    if one.ndim == 1:
        return (0,)
    return (slice(None), 0)
