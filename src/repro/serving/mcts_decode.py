"""MCTS-guided decoding on the unified ``repro.search`` API.

For each emitted token, a search (any registered strategy — default the
paper's pipeline) explores the top-A continuations: Select/Expand/Backup
walk the token tree while the Playout stage evaluates LM rollouts in
``lanes`` parallel lanes.  The chosen root action's token is committed and
the search restarts from the extended prefix.

Two granularities:

* ``mcts_decode``        — one request, one search per token (reference).
* ``mcts_decode_batch``  — B requests; every decode step is ONE device
  program that runs B independent searches via ``search_batch`` (batched
  multi-root search).  Requests share a padded token buffer; true prefix
  lengths ride along as ``LMDecodeDomain.prompt_len``, so the jitted step
  compiles once and is reused for every token of every request.

``make_batched_searcher`` is the factory behind both ``mcts_decode_batch``
and ``ServingEngine``'s MCTS-decode slots (DESIGN.md §5).

KV-cache-aware by default (``MCTSDecodeConfig.cached``): each slot's root
prefix is prefilled once per search via ``CachedLMDecodeDomain`` and the
per-slot cache rows live inside the per-token program, batch-sharded along
the slot axis exactly like ``buf``/``lens`` under a mesh (DESIGN.md §10).
Prompts may be ragged — they share one padded buffer shape with true
lengths riding along as ``prompt_len``.

Cross-token amortization (DESIGN.md §12) — the request-lifecycle rungs:

* ``kv_splice=True`` — commit-time KV splice: the searcher keeps each
  slot's root KV row + next-token logits in a carry, advances them by one
  ``seq_step`` when the token commits, and splices them into the next
  token's search root.  The prompt is prefilled once per request lifetime
  (at slot admission) instead of once per token.
* ``tree_reuse=True`` — cross-token subtree reuse: after committing a
  token the per-slot tree is rerooted on the chosen child
  (``core.tree.reroot``) and its N/W/children statistics seed the next
  search's root as warm-start priors instead of starting cold.

Either knob makes ``make_batched_searcher`` return a ``ReusableSearcher``
(explicit per-slot carry threaded through ``step``); with both off it
returns the stateless per-token function unchanged.

``MCTSDecodeConfig.wave_select`` picks the Select-stage iteration order of
every per-token search (lockstep = one batched UCT pass per tree level,
scan = lane-major; DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.domains.lm_decode import CachedLMDecodeDomain, LMDecodeDomain
from repro.core.tree import init_tree, reroot, reroot_ok
from repro.models.base import ModelConfig, seq_prefill, seq_step
from repro.parallel.compat import (batch_sharding, mesh_num_devices,
                                   replicated_sharding)
from repro.search import SearchConfig, SearchParams, search_batch


@dataclasses.dataclass(frozen=True)
class MCTSDecodeConfig:
    method: str = "pipeline"   # any registered strategy
    num_actions: int = 4
    budget: int = 32           # playouts per emitted token
    lanes: int = 4             # parallel playout stages
    search_depth: int = 8
    rollout_len: int = 4
    cp: float = 1.0
    temperature: float = 1.0
    # KV-cache-aware decode (DESIGN.md §10): each slot's prefix is prefilled
    # once per search and shared by all of that root's expands/playouts via
    # CachedLMDecodeDomain.  False restores the uncached domain (the parity
    # oracle, and a fallback for debugging numerics).
    cached: bool = True
    # Commit-time KV splice (DESIGN.md §12): carry each slot's advanced root
    # KV row across tokens and splice it into the next search instead of
    # re-prefilling.  Needs ``cached``; decisions are unchanged (prefill ==
    # prefill-then-step, the PR-4 parity invariant), only the per-token
    # prefill cost disappears.
    kv_splice: bool = False
    # Cross-token subtree reuse (DESIGN.md §14): reroot the arena on the
    # committed child — the whole surviving subtree (nodes, stats, cached
    # states) IS the next search's starting tree; abandoned rows are
    # recycled through the arena free-list.  Changes exploration
    # (deliberately) — leave off for bit-for-bit parity with cold per-token
    # searches.
    tree_reuse: bool = False
    # Select-stage iteration order inside each per-token search (DESIGN.md
    # §11/§14): "lockstep" descends all of a wave's lanes together with one
    # batched UCT pass per tree level; "scan" is the lane-major original;
    # "mega" fuses the whole wave into kernels/search_wave; "auto" follows
    # SearchParams' resolution.
    wave_select: str = "auto"
    # Kernel implementation for the accelerated paths ("auto" -> Pallas on
    # TPU); threaded into SearchParams.kernels (DESIGN.md §14).
    kernels: str = "auto"
    # In-flight decorrelation statistics inside each per-token search
    # (DESIGN.md §15): "loss" = classic virtual loss, "wu" = WU-UCT
    # unobserved counts (Q from completed playouts only).
    vl_mode: str = "loss"
    # Within-level lane assignment for the depth-major Select paths
    # (DESIGN.md §16): "independent" scores co-located lanes against an
    # identical board; "running" threads the running-assignment scan through
    # the batched level pass so same-parent lanes spread over distinct
    # continuations of the token tree.
    level_assign: str = "independent"
    # Arena capacity per slot for tree_reuse (0 -> 2*budget+2: one search's
    # worth of fresh allocations on top of a carried subtree).  The carry
    # must keep one capacity across tokens, so this is fixed per engine.
    arena_nodes: int = 0

    def __post_init__(self):
        if self.kv_splice and not self.cached:
            raise ValueError("kv_splice carries KV rows across tokens and "
                             "therefore requires cached=True")
        if self.tree_reuse and self.method == "root":
            raise ValueError(
                "tree_reuse reroots the search tree across tokens, but the "
                "'root' strategy keeps no shared tree (SearchResult.tree is "
                "None); pick a tree-bearing method")

    @property
    def stateful(self) -> bool:
        """True when decoding carries per-slot state across tokens."""
        return self.kv_splice or self.tree_reuse

    @property
    def resolved_arena_nodes(self) -> int:
        return self.arena_nodes or 2 * self.budget + 2

    def search_config(self) -> SearchConfig:
        return SearchConfig(
            method=self.method, budget=self.budget, lanes=self.lanes,
            keep_tree=self.tree_reuse,
            # tree_reuse pins every token's tree to ONE arena capacity so
            # the carried arena splices into the next search unchanged
            max_nodes=self.resolved_arena_nodes if self.tree_reuse else 0,
            kernels=self.kernels, wave_select=self.wave_select,
            vl_mode=self.vl_mode, level_assign=self.level_assign,
            params=SearchParams(cp=self.cp, max_depth=self.search_depth,
                                puct=True))


def _domain(cfg: ModelConfig, params, prompt, dcfg: MCTSDecodeConfig,
            prompt_len=None, **extra) -> LMDecodeDomain:
    cls = CachedLMDecodeDomain if dcfg.cached else LMDecodeDomain
    return cls(
        cfg=cfg, params=params, prompt=prompt,
        num_actions=dcfg.num_actions, search_depth=dcfg.search_depth,
        rollout_len=dcfg.rollout_len, temperature=dcfg.temperature,
        prompt_len=prompt_len, **extra)


def mcts_decode(cfg: ModelConfig, params, prompt: np.ndarray,
                n_tokens: int, dcfg: MCTSDecodeConfig, seed: int = 0
                ) -> List[int]:
    """Emit ``n_tokens`` tokens, each chosen by one search per token.

    Delegates to the B=1 batched path: the padded buffer + ``prompt_len``
    keep the searched shapes static, so the whole decode compiles once
    instead of re-jitting as the prefix grows.
    """
    prompt = np.asarray(prompt, np.int32).reshape(1, -1)
    return mcts_decode_batch(cfg, params, prompt, n_tokens, dcfg, seed)[0]


def _resolve_mesh(mesh, batch: int):
    """Shared mesh-resolution rule: None auto-shards real batch parallelism
    over all visible devices, False forces the single-device vmap."""
    if mesh is None and batch > 1 and jax.device_count() > 1:
        from repro.launch.mesh import make_search_mesh
        mesh = make_search_mesh()
    return None if mesh is False else mesh


class ReusableSearcher:
    """Batched per-token searcher with an explicit cross-token carry
    (DESIGN.md §12).  The carry is an opaque per-slot pytree:

    * ``"cache"``/``"logits"`` (``kv_splice``) — each slot's advanced root
      KV row and paired next-token logits, advanced by one ``seq_step``
      when a token commits;
    * ``"arena"``/``"action"``/``"alive"`` (``tree_reuse``) — each slot's
      full search arena from the previous token, the action it committed,
      and a liveness flag.  At the next step the arena is rerooted on the
      committed child (``core.tree.reroot`` — abandoned rows recycled
      through the free-list) and spliced in as the search's starting tree
      (``LMDecodeDomain.root_arena``); a dead/unreusable slot searches
      cold, bit-for-bit.

    Protocol (the engine's request lifecycle maps 1:1 onto it)::

        carry = s.init_carry(buf_len)            # engine start
        carry = s.admit(carry, slot, row, plen)  # request admitted: reset
                                                 # warm, prefill KV row once
        toks, carry = s.step(buf, lens, rng, carry)   # one token for all B

    ``admit`` is the ONLY place a prompt is prefilled; eviction needs no
    call (readmission overwrites the slot), which is exactly the eviction
    contract: a preempted request loses its carry and pays one re-prefill
    of prompt + committed tokens when readmitted.

    Multi-device: slots spread over a 1-D mesh exactly like the stateless
    searcher — every carry leaf is sharded along its leading slot axis
    (DESIGN.md §9); the batch is padded to a device-count multiple and the
    pad rows ride along as permanently-dead slots.
    """

    def __init__(self, cfg: ModelConfig, params, dcfg: MCTSDecodeConfig,
                 batch: int, mesh=None):
        self.cfg, self.params, self.dcfg, self.batch = cfg, params, dcfg, batch
        self.mesh = mesh
        ndev = mesh_num_devices(mesh) if mesh is not None else 1
        self.padded = batch + ((-batch) % ndev)
        self.scfg = dcfg.search_config()
        if mesh is None:
            self._jstep = jax.jit(self._step_impl)
        else:
            shard, repl = batch_sharding(mesh), replicated_sharding(mesh)
            self._jstep = jax.jit(self._step_impl,
                                  in_shardings=(shard, shard, repl, shard),
                                  out_shardings=(shard, shard))
        self._jadmit = jax.jit(self._admit_impl)

    # -- carry lifecycle ----------------------------------------------------
    def init_carry(self, buf_len: int):
        """Identity carry for ``padded`` slots sharing a ``[*, buf_len]``
        token buffer: dead (all-zero) arenas — ``alive`` is False until the
        first search fills them, so every slot's first token searches cold,
        bit-for-bit — and zeroed KV rows (dead until ``admit`` prefills)."""
        d = self.dcfg
        carry = {}
        if d.tree_reuse:
            dummy = _domain(self.cfg, self.params,
                            jnp.zeros((buf_len,), jnp.int32), d,
                            prompt_len=jnp.int32(1))
            shapes = jax.eval_shape(
                lambda: init_tree(dummy, d.resolved_arena_nodes))
            carry["arena"] = jax.tree_util.tree_map(
                lambda s: jnp.zeros((self.padded,) + s.shape, s.dtype),
                shapes)
            carry["action"] = jnp.zeros((self.padded,), jnp.int32)
            carry["alive"] = jnp.zeros((self.padded,), bool)
        if d.kv_splice:
            max_len = buf_len + d.search_depth + d.rollout_len
            lg, cache = jax.eval_shape(
                lambda: seq_prefill(self.cfg, self.params,
                                    jnp.zeros((max_len,), jnp.int32),
                                    jnp.int32(1)))
            carry["logits"] = jnp.zeros((self.padded,) + lg.shape, lg.dtype)
            carry["cache"] = jax.tree_util.tree_map(
                lambda s: jnp.zeros((self.padded,) + s.shape, s.dtype), cache)
        return carry

    def admit(self, carry, slot, buf_row, plen):
        """Reset slot ``slot`` for a fresh request whose padded prefix is
        ``buf_row`` with true length ``plen``: warm stats back to identity,
        KV row prefilled ONCE (the request's only prefill)."""
        return self._jadmit(carry, jnp.int32(slot),
                            jnp.asarray(buf_row, jnp.int32),
                            jnp.int32(plen))

    def _admit_impl(self, carry, slot, buf_row, plen):
        d = self.dcfg
        new = dict(carry)
        if d.tree_reuse:
            # killing the liveness flag IS the reset: a dead slot's next
            # search starts cold and overwrites the stale arena wholesale
            new["alive"] = carry["alive"].at[slot].set(False)
        if d.kv_splice:
            max_len = buf_row.shape[0] + d.search_depth + d.rollout_len
            toks = jnp.zeros((max_len,), jnp.int32)
            toks = jax.lax.dynamic_update_slice(toks, buf_row, (0,))
            logits, cache = seq_prefill(self.cfg, self.params, toks, plen)
            new["cache"] = jax.tree_util.tree_map(
                lambda full, one: full.at[slot].set(one),
                carry["cache"], cache)
            new["logits"] = carry["logits"].at[slot].set(logits)
        return new

    # -- per-token step -----------------------------------------------------
    def step(self, buf, lens, rng, carry):
        """One batched multi-root search over all slots -> each slot's
        chosen token, plus the carry advanced by the committed tokens."""
        buf = jnp.asarray(buf, jnp.int32)
        lens = jnp.asarray(lens, jnp.int32)
        extra = self.padded - self.batch
        if extra:
            buf = jnp.concatenate(
                [buf, jnp.zeros((extra, buf.shape[1]), buf.dtype)])
            lens = jnp.concatenate([lens, jnp.zeros((extra,), lens.dtype)])
        toks, carry = self._jstep(buf, lens, rng, carry)
        return toks[:self.batch], carry

    def _step_impl(self, buf, lens, rng, carry):
        cfg, params, d = self.cfg, self.params, self.dcfg
        if d.tree_reuse:
            # reroot every slot's arena on its committed action (recycling
            # the abandoned rows); a slot is reusable only if it is alive
            # AND the committed child was actually expanded last search
            use = carry["alive"] & jax.vmap(reroot_ok)(
                carry["arena"], carry["action"])
            ar = jax.vmap(reroot)(carry["arena"], carry["action"])
        domains = []
        for i in range(self.padded):
            kw = {}
            if d.kv_splice:
                kw["root_cache"] = jax.tree_util.tree_map(
                    lambda x: x[i], carry["cache"])
                kw["root_logits"] = carry["logits"][i]
            dom = _domain(cfg, params, buf[i], d, prompt_len=lens[i], **kw)
            if d.tree_reuse:
                ar_i = jax.tree_util.tree_map(lambda x: x[i], ar)
                # carried terminal flags reflect the PREVIOUS horizon
                # (len >= plen + depth, and plen just advanced) — refresh
                # them against this token's domain
                ar_i = ar_i.replace(
                    terminal=jax.vmap(dom.is_terminal)(ar_i.state))
                dom = dataclasses.replace(
                    dom, root_arena=ar_i, root_arena_alive=use[i])
            domains.append(dom)
        res = search_batch(domains, self.scfg, rng)
        if d.kv_splice:
            # the carried logits ARE the root's next-token distribution
            tops = jax.vmap(
                lambda lg: jax.lax.top_k(lg, d.num_actions)[1])(
                carry["logits"])
        else:
            def root_topk(buf_row, len_row):
                dom = _domain(cfg, params, buf_row, d, prompt_len=len_row)
                _, top = dom._topk(dom.root_state())
                return top
            tops = jax.vmap(root_topk)(buf, lens)
        toks = tops[jnp.arange(self.padded), res.best_action].astype(jnp.int32)
        new = dict(carry)
        if d.tree_reuse:
            # the searched arenas + committed actions ARE the carry; the
            # reroot happens lazily at the START of the next step
            new["arena"] = res.tree
            new["action"] = res.best_action.astype(jnp.int32)
            new["alive"] = jnp.ones((self.padded,), bool)
        if d.kv_splice:
            # advance each root row by the committed token (ONE step, vs a
            # whole-prefix prefill on the cold path)
            logits, cache = jax.vmap(
                lambda c, t, p: seq_step(cfg, params, c, t, p))(
                carry["cache"], toks, lens)
            new["cache"], new["logits"] = cache, logits
        return toks, new


def make_batched_searcher(cfg: ModelConfig, params, dcfg: MCTSDecodeConfig,
                          batch: int, mesh=None):
    """Factory for the per-token batched searcher.

    Stateless (default): returns ``(token_buf [B, buf_len] i32, lens [B]
    i32, rng) -> [B] i32`` — one jitted device program that searches all B
    prefixes cold and returns each slot's chosen next token.  Shapes are
    static, so one compilation serves every decode step.

    Stateful (``dcfg.kv_splice`` or ``dcfg.tree_reuse``): returns a
    ``ReusableSearcher`` whose ``step`` additionally threads the per-slot
    cross-token carry (spliced KV rows / rerooted subtree stats).

    Multi-device: pass ``mesh`` (1-D, from ``make_search_mesh``) — or rely on
    the default, which shards automatically when more than one device is
    visible — and the searched batch is padded up to a multiple of the device
    count and split along the batch axis, spreading live slots across the
    mesh (DESIGN.md §9).  Pass ``mesh=False`` to force single-device vmap.
    Padded rows consume their own rng splits, so with a mesh the sampled
    token stream differs from the unsharded searcher (same distribution).
    """
    mesh = _resolve_mesh(mesh, batch)
    if dcfg.stateful:
        return ReusableSearcher(cfg, params, dcfg, batch, mesh=mesh)

    scfg = dcfg.search_config()
    ndev = mesh_num_devices(mesh) if mesh is not None else 1
    padded = batch + ((-batch) % ndev)

    def root_topk(buf_row, len_row):
        d = _domain(cfg, params, buf_row, dcfg, prompt_len=len_row)
        _, top = d._topk(d.root_state())
        return top

    def step(buf, lens, rng):
        domains = [_domain(cfg, params, buf[i], dcfg, prompt_len=lens[i])
                   for i in range(padded)]
        res = search_batch(domains, scfg, rng)
        tops = jax.vmap(root_topk)(buf, lens)            # [padded, A], one pass
        return tops[jnp.arange(padded), res.best_action].astype(jnp.int32)

    if mesh is None:
        return jax.jit(step)

    # the batch axis of buf/lens (and of every intermediate, via sharding
    # propagation) is split over the mesh; the scalar rng key is replicated
    shard = batch_sharding(mesh)
    repl = replicated_sharding(mesh)
    jstep = jax.jit(step, in_shardings=(shard, shard, repl),
                    out_shardings=shard)

    def sharded_step(buf, lens, rng):
        extra = padded - batch
        if extra:
            # pad dead rows (len 0 == empty slot: searched, output ignored)
            buf = jnp.concatenate(
                [buf, jnp.zeros((extra, buf.shape[1]), buf.dtype)])
            lens = jnp.concatenate([lens, jnp.zeros((extra,), lens.dtype)])
        return jstep(buf, lens, rng)[:batch]

    return sharded_step


def _pad_prompts(prompts, n_tokens: int):
    """Normalize equal-length [B, plen] or ragged list-of-sequences prompts
    into (padded buffer [B, max_plen + n_tokens] i32, true lengths [B] i32).
    """
    if isinstance(prompts, (list, tuple)):
        rows = [np.asarray(p, np.int32) for p in prompts]
        if any(r.ndim != 1 for r in rows):
            raise ValueError("ragged prompts must be a list of 1-D token "
                             f"sequences, got ndims {[r.ndim for r in rows]}")
    else:
        arr = np.asarray(prompts, np.int32)   # np or jax array-likes
        if arr.ndim != 2:
            raise ValueError("prompts must be [B, plen] or a (ragged) list "
                             f"of 1-D sequences, got shape {arr.shape}")
        rows = list(arr)
    if not rows:
        raise ValueError("prompts must contain at least one request")
    lens = np.array([len(r) for r in rows], np.int32)
    if (lens == 0).any():
        raise ValueError("every prompt needs at least one token, got "
                         f"lengths {lens.tolist()}")
    buf = np.zeros((len(rows), int(lens.max()) + n_tokens), np.int32)
    for i, r in enumerate(rows):
        buf[i, : len(r)] = r
    return buf, lens


def mcts_decode_batch(cfg: ModelConfig, params, prompts,
                      n_tokens: int, dcfg: MCTSDecodeConfig, seed: int = 0,
                      mesh=None) -> List[List[int]]:
    """Decode B prompts together: each of the ``n_tokens`` steps is a single
    batched multi-root search over all requests.

    ``prompts`` is [B, plen] int32 OR a ragged list of 1-D token sequences:
    requests are padded to one buffer shape and their true lengths ride
    along as ``LMDecodeDomain.prompt_len``, so mixed-length batches compile
    to the same single program as equal-length ones.  ``mesh`` as in
    ``make_batched_searcher``: None auto-shards the searched batch over
    multiple devices, False forces single-device vmap.

    With ``dcfg.kv_splice``/``dcfg.tree_reuse`` the per-request carry is
    threaded across the token loop: every prompt is prefilled once up front
    and each committed token costs one incremental step (DESIGN.md §12).
    """
    buf, lens = _pad_prompts(prompts, n_tokens)
    b = buf.shape[0]
    searcher = make_batched_searcher(cfg, params, dcfg, batch=b, mesh=mesh)
    rng = jax.random.key(seed)
    out: List[List[int]] = [[] for _ in range(b)]
    carry = None
    if dcfg.stateful:
        carry = searcher.init_carry(buf.shape[1])
        for i in range(b):
            carry = searcher.admit(carry, i, buf[i], lens[i])
    for _ in range(n_tokens):
        rng, sub = jax.random.split(rng)
        if dcfg.stateful:
            toks, carry = searcher.step(buf, lens, sub, carry)
            toks = np.asarray(toks)
        else:
            toks = np.asarray(
                searcher(jnp.asarray(buf), jnp.asarray(lens), sub))
        for i in range(b):
            out[i].append(int(toks[i]))
            buf[i, lens[i]] = toks[i]
        lens += 1
    return out
