"""MCTS-guided decoding on the unified ``repro.search`` API.

For each emitted token, a search (any registered strategy — default the
paper's pipeline) explores the top-A continuations: Select/Expand/Backup
walk the token tree while the Playout stage evaluates LM rollouts in
``lanes`` parallel lanes.  The chosen root action's token is committed and
the search restarts from the extended prefix.

Two granularities:

* ``mcts_decode``        — one request, one search per token (reference).
* ``mcts_decode_batch``  — B requests; every decode step is ONE device
  program that runs B independent searches via ``search_batch`` (batched
  multi-root search).  Requests share a padded token buffer; true prefix
  lengths ride along as ``LMDecodeDomain.prompt_len``, so the jitted step
  compiles once and is reused for every token of every request.

``make_batched_searcher`` is the factory behind both ``mcts_decode_batch``
and ``ServingEngine``'s MCTS-decode slots (DESIGN.md §5).

KV-cache-aware by default (``MCTSDecodeConfig.cached``): each slot's root
prefix is prefilled once per search via ``CachedLMDecodeDomain`` and the
per-slot cache rows live inside the per-token program, batch-sharded along
the slot axis exactly like ``buf``/``lens`` under a mesh (DESIGN.md §10).
Prompts may be ragged — they share one padded buffer shape with true
lengths riding along as ``prompt_len``.

``MCTSDecodeConfig.wave_select`` picks the Select-stage iteration order of
every per-token search (lockstep = one batched UCT pass per tree level,
scan = lane-major; DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.domains.lm_decode import CachedLMDecodeDomain, LMDecodeDomain
from repro.models.base import ModelConfig
from repro.parallel.compat import (batch_sharding, mesh_num_devices,
                                   replicated_sharding)
from repro.search import SearchConfig, SearchParams, search_batch


@dataclasses.dataclass(frozen=True)
class MCTSDecodeConfig:
    method: str = "pipeline"   # any registered strategy
    num_actions: int = 4
    budget: int = 32           # playouts per emitted token
    lanes: int = 4             # parallel playout stages
    search_depth: int = 8
    rollout_len: int = 4
    cp: float = 1.0
    temperature: float = 1.0
    # KV-cache-aware decode (DESIGN.md §10): each slot's prefix is prefilled
    # once per search and shared by all of that root's expands/playouts via
    # CachedLMDecodeDomain.  False restores the uncached domain (the parity
    # oracle, and a fallback for debugging numerics).
    cached: bool = True
    # Select-stage iteration order inside each per-token search (DESIGN.md
    # §11): "lockstep" descends all of a wave's lanes together with one
    # batched UCT pass per tree level; "scan" is the lane-major original;
    # "auto" follows SearchParams' resolution (lockstep iff use_pallas).
    wave_select: str = "auto"

    def search_config(self) -> SearchConfig:
        return SearchConfig(
            method=self.method, budget=self.budget, lanes=self.lanes,
            keep_tree=False,
            params=SearchParams(cp=self.cp, max_depth=self.search_depth,
                                puct=True, wave_select=self.wave_select))


def _domain(cfg: ModelConfig, params, prompt, dcfg: MCTSDecodeConfig,
            prompt_len=None) -> LMDecodeDomain:
    cls = CachedLMDecodeDomain if dcfg.cached else LMDecodeDomain
    return cls(
        cfg=cfg, params=params, prompt=prompt,
        num_actions=dcfg.num_actions, search_depth=dcfg.search_depth,
        rollout_len=dcfg.rollout_len, temperature=dcfg.temperature,
        prompt_len=prompt_len)


def mcts_decode(cfg: ModelConfig, params, prompt: np.ndarray,
                n_tokens: int, dcfg: MCTSDecodeConfig, seed: int = 0
                ) -> List[int]:
    """Emit ``n_tokens`` tokens, each chosen by one search per token.

    Delegates to the B=1 batched path: the padded buffer + ``prompt_len``
    keep the searched shapes static, so the whole decode compiles once
    instead of re-jitting as the prefix grows.
    """
    prompt = np.asarray(prompt, np.int32).reshape(1, -1)
    return mcts_decode_batch(cfg, params, prompt, n_tokens, dcfg, seed)[0]


def make_batched_searcher(cfg: ModelConfig, params, dcfg: MCTSDecodeConfig,
                          batch: int, mesh=None) -> Callable:
    """``(token_buf [B, buf_len] i32, lens [B] i32, rng) -> [B] i32``: one
    jitted device program that searches all B prefixes and returns each
    slot's chosen next token.  Shapes are static, so one compilation serves
    every decode step.

    Multi-device: pass ``mesh`` (1-D, from ``make_search_mesh``) — or rely on
    the default, which shards automatically when more than one device is
    visible — and the searched batch is padded up to a multiple of the device
    count and split along the batch axis, spreading live slots across the
    mesh (DESIGN.md §9).  Pass ``mesh=False`` to force single-device vmap.
    Padded rows consume their own rng splits, so with a mesh the sampled
    token stream differs from the unsharded searcher (same distribution).
    """
    scfg = dcfg.search_config()
    # auto-shard only real batch parallelism: a 1-slot searcher padded to the
    # mesh would run device_count searches per token to keep one
    if mesh is None and batch > 1 and jax.device_count() > 1:
        from repro.launch.mesh import make_search_mesh
        mesh = make_search_mesh()
    if mesh is False:
        mesh = None

    ndev = mesh_num_devices(mesh) if mesh is not None else 1
    padded = batch + ((-batch) % ndev)

    def root_topk(buf_row, len_row):
        d = _domain(cfg, params, buf_row, dcfg, prompt_len=len_row)
        _, top = d._topk(d.root_state())
        return top

    def step(buf, lens, rng):
        domains = [_domain(cfg, params, buf[i], dcfg, prompt_len=lens[i])
                   for i in range(padded)]
        res = search_batch(domains, scfg, rng)
        tops = jax.vmap(root_topk)(buf, lens)            # [padded, A], one pass
        return tops[jnp.arange(padded), res.best_action].astype(jnp.int32)

    if mesh is None:
        return jax.jit(step)

    # the batch axis of buf/lens (and of every intermediate, via sharding
    # propagation) is split over the mesh; the scalar rng key is replicated
    shard = batch_sharding(mesh)
    repl = replicated_sharding(mesh)
    jstep = jax.jit(step, in_shardings=(shard, shard, repl),
                    out_shardings=shard)

    def sharded_step(buf, lens, rng):
        extra = padded - batch
        if extra:
            # pad dead rows (len 0 == empty slot: searched, output ignored)
            buf = jnp.concatenate(
                [buf, jnp.zeros((extra, buf.shape[1]), buf.dtype)])
            lens = jnp.concatenate([lens, jnp.zeros((extra,), lens.dtype)])
        return jstep(buf, lens, rng)[:batch]

    return sharded_step


def _pad_prompts(prompts, n_tokens: int):
    """Normalize equal-length [B, plen] or ragged list-of-sequences prompts
    into (padded buffer [B, max_plen + n_tokens] i32, true lengths [B] i32).
    """
    if isinstance(prompts, (list, tuple)):
        rows = [np.asarray(p, np.int32) for p in prompts]
        if any(r.ndim != 1 for r in rows):
            raise ValueError("ragged prompts must be a list of 1-D token "
                             f"sequences, got ndims {[r.ndim for r in rows]}")
    else:
        arr = np.asarray(prompts, np.int32)   # np or jax array-likes
        if arr.ndim != 2:
            raise ValueError("prompts must be [B, plen] or a (ragged) list "
                             f"of 1-D sequences, got shape {arr.shape}")
        rows = list(arr)
    if not rows:
        raise ValueError("prompts must contain at least one request")
    lens = np.array([len(r) for r in rows], np.int32)
    if (lens == 0).any():
        raise ValueError("every prompt needs at least one token, got "
                         f"lengths {lens.tolist()}")
    buf = np.zeros((len(rows), int(lens.max()) + n_tokens), np.int32)
    for i, r in enumerate(rows):
        buf[i, : len(r)] = r
    return buf, lens


def mcts_decode_batch(cfg: ModelConfig, params, prompts,
                      n_tokens: int, dcfg: MCTSDecodeConfig, seed: int = 0,
                      mesh=None) -> List[List[int]]:
    """Decode B prompts together: each of the ``n_tokens`` steps is a single
    batched multi-root search over all requests.

    ``prompts`` is [B, plen] int32 OR a ragged list of 1-D token sequences:
    requests are padded to one buffer shape and their true lengths ride
    along as ``LMDecodeDomain.prompt_len``, so mixed-length batches compile
    to the same single program as equal-length ones.  ``mesh`` as in
    ``make_batched_searcher``: None auto-shards the searched batch over
    multiple devices, False forces single-device vmap.
    """
    buf, lens = _pad_prompts(prompts, n_tokens)
    b = buf.shape[0]
    searcher = make_batched_searcher(cfg, params, dcfg, batch=b, mesh=mesh)
    rng = jax.random.key(seed)
    out: List[List[int]] = [[] for _ in range(b)]
    for _ in range(n_tokens):
        rng, sub = jax.random.split(rng)
        toks = np.asarray(searcher(jnp.asarray(buf), jnp.asarray(lens), sub))
        for i in range(b):
            out[i].append(int(toks[i]))
            buf[i, lens[i]] = toks[i]
        lens += 1
    return out
