"""Pipeline-MCTS-guided decoding — the paper's technique as a serving feature.

For each emitted token, a pipelined MCTS (repro.core.pipeline) searches the
top-A continuations: Select/Expand/Backup walk the token tree while the
Playout stage evaluates LM rollouts in ``lanes`` parallel lanes (the
nonlinear pipeline's replicated playout stages — on TPU, a batched/sharded
forward).  The chosen root action's token is committed and the search
restarts from the extended prefix.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.domains.lm_decode import LMDecodeDomain
from repro.core.pipeline import PipelineConfig, run_pipeline
from repro.core.stages import SearchParams
from repro.core.tree import root_action_by_visits
from repro.models.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class MCTSDecodeConfig:
    num_actions: int = 4
    budget: int = 32           # playouts per emitted token
    lanes: int = 4             # parallel playout stages
    search_depth: int = 8
    rollout_len: int = 4
    cp: float = 1.0
    temperature: float = 1.0


def mcts_decode(cfg: ModelConfig, params, prompt: np.ndarray,
                n_tokens: int, dcfg: MCTSDecodeConfig, seed: int = 0
                ) -> List[int]:
    """Emit ``n_tokens`` tokens, each chosen by a pipelined MCTS search."""
    out: List[int] = []
    prefix = jnp.asarray(prompt, jnp.int32)
    rng = jax.random.key(seed)

    sp = SearchParams(cp=dcfg.cp, max_depth=dcfg.search_depth, puct=True)
    pcfg = PipelineConfig(budget=dcfg.budget, lanes=dcfg.lanes, params=sp)

    @jax.jit
    def search(prefix, rng):
        domain = LMDecodeDomain(
            cfg=cfg, params=params, prompt=prefix,
            num_actions=dcfg.num_actions, search_depth=dcfg.search_depth,
            rollout_len=dcfg.rollout_len, temperature=dcfg.temperature)
        tree, stats = run_pipeline(domain, pcfg, rng)
        action = root_action_by_visits(tree)
        root_state = domain.root_state()
        _, top_toks = domain._topk(root_state)
        return top_toks[action], stats["duplicates"]

    for _ in range(n_tokens):
        rng, sub = jax.random.split(rng)
        tok, _ = search(prefix, sub)
        tok = int(tok)
        out.append(tok)
        prefix = jnp.concatenate([prefix, jnp.asarray([tok], jnp.int32)])
    return out
