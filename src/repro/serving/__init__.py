from repro.serving.engine import EngineConfig, Request, ServingEngine  # noqa: F401
from repro.serving.mcts_decode import (MCTSDecodeConfig,  # noqa: F401
                                       make_batched_searcher, mcts_decode,
                                       mcts_decode_batch)
