from repro.serving.engine import EngineConfig, ServingEngine  # noqa: F401
from repro.serving.mcts_decode import (MCTSDecodeConfig,  # noqa: F401
                                       ReusableSearcher, make_batched_searcher,
                                       mcts_decode, mcts_decode_batch)
from repro.serving.scheduler import (POLICIES, Admit, Evict,  # noqa: F401
                                     Request, RequestScheduler)
from repro.serving.stats import ServingStats, percentile  # noqa: F401
