"""Serving metrics: per-request lifecycle timings + engine-level counters.

``ServingStats`` is the metrics half of the request-lifecycle subsystem
(DESIGN.md §12).  The engine calls one hook per lifecycle transition
(``on_submit`` / ``on_admit`` / ``on_token`` / ``on_preempt`` /
``on_finish`` / ``on_step``) and ``snapshot()`` flattens everything into
one ``{"serving/<metric>": float}`` dict — the wandb-log idiom (HomebrewNLP
``wandblog.py``): flat slash-prefixed keys, cheap to compute, safe to call
at any point in the run, ready to hand to any scalar logger.

Tracked per request (keyed by ``Request.uid``):

* ``queue_wait``  — submit -> first admission into a slot
* ``ttft``        — submit -> first emitted token (time to first token)
* ``latency``     — submit -> finish
* per-token gaps  — interval between consecutive emitted tokens
* ``preemptions`` — times the request was evicted and requeued

Engine-level: requests submitted/admitted/finished, preemption events,
tokens, steps, wall tokens/s.  Distributions keep a bounded sample list and
report nearest-rank p50/p95.

All timestamps come from one injectable monotonic ``clock`` so latencies
are well defined; tests may pass a fake clock for determinism.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional


def percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a non-empty list."""
    if not xs:
        raise ValueError("percentile of an empty sample")
    s = sorted(xs)
    k = max(0, min(len(s) - 1, int(-(-q / 100.0 * len(s) // 1)) - 1))
    return float(s[k])


class Series:
    """Bounded sample series: count/sum always exact, percentiles over the
    first ``max_samples`` observations (enough for serving dashboards; exact
    in every test-sized run)."""

    def __init__(self, max_samples: int = 4096):
        self.max_samples = max_samples
        self.samples: List[float] = []
        self.count = 0
        self.total = 0.0

    def add(self, v: float) -> None:
        self.count += 1
        self.total += float(v)
        if len(self.samples) < self.max_samples:
            self.samples.append(float(v))

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def p(self, q: float) -> float:
        return percentile(self.samples, q) if self.samples else 0.0

    def summary(self, name: str) -> Dict[str, float]:
        if not self.count:
            return {}
        return {f"{name}_mean": self.mean, f"{name}_p50": self.p(50),
                f"{name}_p95": self.p(95)}


@dataclasses.dataclass
class RequestTiming:
    """Lifecycle timestamps of one request (all from ``ServingStats.now``)."""
    enqueue_t: float
    admit_t: Optional[float] = None        # first admission only
    first_token_t: Optional[float] = None
    last_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    tokens: int = 0
    preemptions: int = 0

    def summary(self) -> Dict[str, Any]:
        done = self.finish_t is not None
        return {
            "queue_wait": (self.admit_t - self.enqueue_t
                           if self.admit_t is not None else None),
            "ttft": (self.first_token_t - self.enqueue_t
                     if self.first_token_t is not None else None),
            "latency": self.finish_t - self.enqueue_t if done else None,
            "tokens": self.tokens,
            "preemptions": self.preemptions,
            "done": done,
        }


class ServingStats:
    """Engine-level counters + per-request timings with a flat snapshot."""

    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 max_samples: int = 4096):
        self._clock = clock
        self.requests: Dict[int, RequestTiming] = {}
        self.queue_wait = Series(max_samples)
        self.ttft = Series(max_samples)
        self.token_latency = Series(max_samples)   # inter-token gaps
        self.request_latency = Series(max_samples)
        self.submitted = 0
        self.admissions = 0
        self.finished = 0
        self.preemptions = 0
        self.tokens = 0
        self.steps = 0
        self.searches = 0
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None

    def now(self) -> float:
        return self._clock()

    # -- lifecycle hooks ----------------------------------------------------
    def on_submit(self, uid: int, t: float) -> None:
        self.submitted += 1
        self.requests[uid] = RequestTiming(enqueue_t=t)
        if self._t0 is None:
            self._t0 = t
        self._t_last = t

    def on_admit(self, uid: int, t: float) -> None:
        self.admissions += 1
        r = self.requests[uid]
        if r.admit_t is None:                      # first admission only
            r.admit_t = t
            self.queue_wait.add(t - r.enqueue_t)
        self._t_last = t

    def on_token(self, uid: int, t: float) -> None:
        r = self.requests[uid]
        r.tokens += 1
        self.tokens += 1
        if r.first_token_t is None:
            r.first_token_t = t
            self.ttft.add(t - r.enqueue_t)
        else:
            self.token_latency.add(t - r.last_token_t)
        r.last_token_t = t
        self._t_last = t

    def on_preempt(self, uid: int, t: float) -> None:
        self.preemptions += 1
        self.requests[uid].preemptions += 1
        self._t_last = t

    def on_finish(self, uid: int, t: float) -> None:
        self.finished += 1
        r = self.requests[uid]
        r.finish_t = t
        self.request_latency.add(t - r.enqueue_t)
        self._t_last = t

    def on_step(self, emitted: int, searched: int = 0) -> None:
        self.steps += 1
        self.searches += searched

    # -- reporting ----------------------------------------------------------
    def request_summaries(self) -> Dict[int, Dict[str, Any]]:
        return {uid: r.summary() for uid, r in self.requests.items()}

    def snapshot(self) -> Dict[str, float]:
        """Flat ``{"serving/<metric>": float}`` dict (wandblog idiom)."""
        out = {
            "serving/requests_submitted": float(self.submitted),
            "serving/requests_admitted": float(self.admissions),
            "serving/requests_finished": float(self.finished),
            "serving/preemptions": float(self.preemptions),
            "serving/tokens": float(self.tokens),
            "serving/steps": float(self.steps),
            "serving/searches": float(self.searches),
        }
        if self._t0 is not None and self._t_last is not None:
            wall = self._t_last - self._t0
            out["serving/wall_s"] = wall
            if wall > 0:
                out["serving/tokens_per_s"] = self.tokens / wall
        for name, series in (("queue_wait", self.queue_wait),
                             ("ttft", self.ttft),
                             ("token_latency", self.token_latency),
                             ("request_latency", self.request_latency)):
            out.update({f"serving/{k}": v
                        for k, v in series.summary(name).items()})
        return out
