"""Assigned input-shape set (applies to every architecture in the pool)."""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# long_500k requires sub-quadratic attention: run only for SSM/hybrid archs
# (rwkv6 O(1)-state; zamba2 Mamba2 + a handful of shared-attn KV caches).
# Pure full-attention archs skip it — recorded per cell in EXPERIMENTS.md.
LONG_CONTEXT_ARCHS = ("rwkv6-1.6b", "zamba2-1.2b")


def cell_enabled(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True
