"""minicpm-2b [dense] — arXiv:2404.06395 (WSD schedule; llama-like arch).

40L d_model=2304 36H MHA d_ff=5760 vocab=122753, depth-scaled residuals
(1.4/sqrt(40)), mup logit scaling (256/2304), tied embeddings.
Train driver pairs this arch with the WSD schedule (repro.optim.schedules.wsd).
"""
import math

from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab_size=122753, tie_embeddings=True,
    residual_scale=1.4 / math.sqrt(40), logit_scale=256.0 / 2304.0,
    attn_impl="blocked", dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="minicpm-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, tie_embeddings=True,
    residual_scale=1.4 / math.sqrt(3), logit_scale=0.5,
    dtype="float32", remat=False, ce_chunk=16,
)
