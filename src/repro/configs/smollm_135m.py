"""smollm-135m [dense] — hf:HuggingFaceTB/SmolLM-135M (llama arch).

30L d_model=576 9H GQA(kv=3) d_ff=1536 vocab=49152, tied embeddings.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536,
    vocab_size=49152, tie_embeddings=True, attn_impl="blocked", dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="smollm-smoke", family="dense",
    n_layers=3, d_model=48, n_heads=3, n_kv_heads=1, d_ff=128,
    vocab_size=256, tie_embeddings=True, dtype="float32", remat=False,
    ce_chunk=16,
)
