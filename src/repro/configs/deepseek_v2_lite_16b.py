"""deepseek-v2-lite-16b [moe] — arXiv:2405.04434 (HF config).

27L d_model=2048 16H MLA(kv_lora=512, qk_nope=128, qk_rope=64, v=128),
64 routed experts top-6 + 2 shared (d_ff_expert=1408), first layer dense
(d_ff=10944), vocab=102400.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, d_ff=0, vocab_size=102400,
    use_mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    n_experts=64, n_shared_experts=2, moe_topk=6, d_ff_expert=1408,
    first_dense_layers=1, d_ff_dense=10944,
    rope_theta=10_000.0, attn_impl="blocked", moe_groups=32, dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-v2-lite-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, d_ff=0, vocab_size=256,
    use_mla=True, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16,
    n_experts=8, n_shared_experts=2, moe_topk=2, d_ff_expert=32,
    first_dense_layers=1, d_ff_dense=128,
    dtype="float32", remat=False, ce_chunk=16,
)
