"""zamba2-1.2b [hybrid] — arXiv:2411.15242 (HF config).

38 Mamba2 blocks d_model=2048 (ssm_state=64, expand 2, head_dim 64) + one
shared attention block at width 2D (32H x 128) with d_ff=8192, applied every
6 blocks with per-application LoRA; vocab=32000.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="zamba2",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_head=128,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    shared_attn_every=6, attn_impl="blocked", dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="zamba2-smoke", family="zamba2",
    n_layers=5, d_model=32, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab_size=256,
    ssm_state=8, ssm_expand=2, ssm_head_dim=8, shared_attn_every=2,
    dtype="float32", remat=False, ce_chunk=16,
)
