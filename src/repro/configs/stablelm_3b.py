"""stablelm-3b [dense] — hf:stabilityai/stablelm-2 family (unverified tier).

32L d_model=2560 32H MHA d_ff=6912 vocab=50304, LayerNorm, partial rotary 25%.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912,
    vocab_size=50304, norm="layernorm", rope_frac=0.25, attn_impl="blocked", dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="stablelm-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=192,
    vocab_size=256, norm="layernorm", rope_frac=0.25,
    dtype="float32", remat=False, ce_chunk=16,
)
