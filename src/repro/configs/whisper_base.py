"""whisper-base [audio] — arXiv:2212.04356 (enc-dec; conv frontend STUB).

6L enc + 6L dec, d_model=512 8H MHA d_ff=2048 vocab=51865, GELU, LayerNorm,
tied decoder embeddings. input_specs provides precomputed frame embeddings
[B, 1500, 512]. max_seq sized for the assigned decode_32k cell (shape-level;
real Whisper caps at 448 decoder positions).
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="whisper",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, d_ff=2048,
    vocab_size=51865, norm="layernorm", act="gelu", qkv_bias=True,
    tie_embeddings=True, enc_seq=1500, max_seq=32768, attn_impl="blocked", dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-smoke", family="whisper",
    n_layers=2, n_enc_layers=2, d_model=48, n_heads=4, d_ff=96,
    vocab_size=256, norm="layernorm", act="gelu", qkv_bias=True,
    tie_embeddings=True, enc_seq=16, max_seq=64,
    dtype="float32", remat=False, ce_chunk=16,
)
