"""grok-1-314b [moe] — hf:xai-org/grok-1 (unverified tier).

64L d_model=6144 48H GQA(kv=8, d_head=128), 8 experts top-2 d_ff=32768,
vocab=131072, attention-logit tanh soft-cap 30.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=0, vocab_size=131072,
    n_experts=8, moe_topk=2, d_ff_expert=32768,
    logits_soft_cap=30.0, attn_impl="blocked", moe_groups=32, dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="grok-1-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=0, vocab_size=256,
    n_experts=4, moe_topk=2, d_ff_expert=64,
    logits_soft_cap=30.0, dtype="float32", remat=False, ce_chunk=16,
)
