"""Architecture registry: one module per assigned architecture.

``get_config(arch)`` -> full ModelConfig (exact published dims);
``get_smoke_config(arch)`` -> reduced same-family config for CPU tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.base import ModelConfig

ARCHS: List[str] = [
    "deepseek-v2-lite-16b",
    "grok-1-314b",
    "smollm-135m",
    "qwen2-0.5b",
    "minicpm-2b",
    "stablelm-3b",
    "whisper-base",
    "rwkv6-1.6b",
    "zamba2-1.2b",
    "internvl2-2b",
]

_MODULES: Dict[str, str] = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(arch).SMOKE_CONFIG
