"""rwkv6-1.6b "Finch" [ssm] — arXiv:2404.05892 (unverified tier).

24L d_model=2048 (attn-free; 32 heads x 64), channel-mix d_ff=7168,
vocab=65536, data-dependent decay via LoRA (decay_lora=64, mix_lora=32).
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="rwkv6",
    n_layers=24, d_model=2048, n_heads=32, d_ff=7168, vocab_size=65536,
    rwkv_head_dim=64, rwkv_decay_lora=64, rwkv_mix_lora=32, dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="rwkv6-smoke", family="rwkv6",
    n_layers=2, d_model=32, n_heads=4, d_ff=96, vocab_size=256,
    rwkv_head_dim=8, rwkv_decay_lora=8, rwkv_mix_lora=4,
    dtype="float32", remat=False, ce_chunk=16,
)
