"""qwen2-0.5b [dense] — arXiv:2407.10671 (HF config).

24L d_model=896 14H GQA(kv=2) d_ff=4864 vocab=151936, QKV bias, tied
embeddings, rope theta 1e6.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151936, qkv_bias=True, tie_embeddings=True,
    rope_theta=1_000_000.0, attn_impl="blocked", dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, qkv_bias=True, tie_embeddings=True,
    dtype="float32", remat=False, ce_chunk=16,
)
