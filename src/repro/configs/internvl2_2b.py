"""internvl2-2b [vlm] — arXiv:2404.16821 (InternViT stub + InternLM2-1.8B).

LM backbone: 24L d_model=2048 16H GQA(kv=8) d_ff=8192 vocab=92553.
Vision tower is a STUB: input_specs provides InternViT patch features
[B, 256, 1024]; the real LM-side projector (mlp1) is implemented.
"""
from repro.models.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
    vocab_size=92553, n_patches=256, frontend_dim=1024, attn_impl="blocked", dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="internvl2-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, n_patches=8, frontend_dim=32,
    dtype="float32", remat=False, ce_chunk=16,
)
