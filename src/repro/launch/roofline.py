"""Roofline analysis from dry-run records (deliverable g).

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  Per (arch x shape x mesh) cell:

    compute term    = HLO_FLOPs/device        / peak_FLOPs
    memory term     = HLO_bytes/device        / HBM_bw
    collective term = link_bytes/device       / link_bw

(the dry-run's per-device HLO numbers are loop-aware — see hlo_analysis.py).

MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (inference);
``useful ratio`` = MODEL_FLOPS/device / HLO_FLOPs/device catches remat and
redundant (replicated) compute.  ``roofline frac`` = useful-compute time /
dominant term — the score the perf loop drives up.

  PYTHONPATH=src python -m repro.launch.roofline experiments/dryrun_16x16.json
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
LINK_BW = 50e9               # B/s per ICI link

KIND = {"train_4k": "train", "prefill_32k": "prefill", "decode_32k": "decode",
        "long_500k": "decode"}


def model_flops(rec: Dict[str, Any]) -> float:
    n_act = rec.get("active_params", rec.get("params", 0))
    toks = rec.get("tokens", 0)
    kind = KIND.get(rec["shape"], "train")
    per_token = 6 * n_act if kind == "train" else 2 * n_act
    return per_token * toks


def analyze(rec: Dict[str, Any]) -> Dict[str, Any]:
    dev = rec["devices"]
    fl = rec.get("flops_per_device", 0.0)
    by = rec.get("hbm_bytes_per_device", 0.0)
    lk = rec.get("link_bytes_per_device", 0.0)
    t_c = fl / PEAK_FLOPS
    t_m = by / HBM_BW
    t_l = lk / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_l, "collective"))
    mf = model_flops(rec) / dev
    useful = mf / fl if fl else 0.0
    frac = (mf / PEAK_FLOPS) / dom[0] if dom[0] else 0.0
    return {"t_compute": t_c, "t_memory": t_m, "t_collective": t_l,
            "dominant": dom[1], "model_flops_dev": mf,
            "useful_ratio": useful, "roofline_frac": frac}


def suggestion(rec, a) -> str:
    if a["dominant"] == "collective":
        top = max(rec.get("collectives", {"?": {"link_bytes": 0}}).items(),
                  key=lambda kv: kv[1].get("link_bytes", 0))[0]
        return f"cut {top} volume (sharding/overlap)"
    if a["dominant"] == "memory":
        return "reduce HBM traffic (fusion, dtype, remat policy)"
    if a["useful_ratio"] < 0.4:
        return "remove redundant compute (replicated attention / remat)"
    return "compute-bound at good utilization; overlap remaining comm"


def table(records: List[Dict[str, Any]]) -> str:
    rows = ["| arch | shape | mesh | compute s | memory s | collective s | "
            "dominant | useful | roofline frac | next lever |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for rec in records:
        if rec.get("status") == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                        f"— | — | — | skipped | — | — | {rec['reason'][:42]} |")
            continue
        if rec.get("status") != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                        f"ERR | | | | | | {rec.get('error', '')[:40]} |")
            continue
        a = analyze(rec)
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {a['t_compute']:.3e} | {a['t_memory']:.3e} "
            f"| {a['t_collective']:.3e} | **{a['dominant']}** "
            f"| {a['useful_ratio']:.2f} | {a['roofline_frac']:.3f} "
            f"| {suggestion(rec, a)} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("json_files", nargs="+")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    records = []
    for f in args.json_files:
        with open(f) as fh:
            records.extend(json.load(fh))
    md = table(records)
    print(md)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(md + "\n")


if __name__ == "__main__":
    main()
