"""Step factories: train_step / prefill_step / decode_step for any arch.

These are the functions the dry-run lowers and the drivers execute; they are
pure (params, state, batch) -> (new state, metrics) and rely on
with_logical_constraint for activation sharding under an active mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig, get_family
from repro.optim import Optimizer, apply_updates, clip_by_global_norm
from repro.parallel.sharding import with_logical_constraint


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    schedule: Callable, grad_clip: float = 1.0,
                    compress_grads: Optional[Callable] = None):
    fam = get_family(cfg)

    def train_step(params, opt_state, batch):
        batch = {k: with_logical_constraint(v, ("batch",) + (None,) * (v.ndim - 1))
                 for k, v in batch.items()}
        (loss, aux), grads = jax.value_and_grad(
            lambda p: fam.loss_fn(cfg, p, batch), has_aux=True)(params)
        if compress_grads is not None:
            grads = compress_grads(grads)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = schedule(opt_state["step"])
        updates, opt_state = optimizer.update(grads, opt_state, params, lr)
        params = apply_updates(params, updates)
        metrics = {"loss": aux["loss"], "grad_norm": gnorm, "lr": lr}
        if "aux_loss" in aux:
            metrics["aux_loss"] = aux["aux_loss"]
        return params, opt_state, metrics

    return train_step


def make_grad_accum_train_step(cfg: ModelConfig, optimizer: Optimizer,
                               schedule: Callable, n_micro: int,
                               grad_clip: float = 1.0):
    """Gradient accumulation over n_micro microbatches (scan over leading dim)."""
    fam = get_family(cfg)

    def train_step(params, opt_state, batch):
        # batch leaves have shape [n_micro, micro_batch, ...]
        def micro(accum, mb):
            (loss, aux), g = jax.value_and_grad(
                lambda p: fam.loss_fn(cfg, p, mb), has_aux=True)(params)
            accum = jax.tree_util.tree_map(lambda a, b: a + b, accum, g)
            return accum, loss

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, losses = jax.lax.scan(micro, zeros, batch)
        grads = jax.tree_util.tree_map(lambda g: (g / n_micro).astype(cfg.jdtype), grads)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = schedule(opt_state["step"])
        updates, opt_state = optimizer.update(grads, opt_state, params, lr)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": losses.mean(), "grad_norm": gnorm, "lr": lr}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    fam = get_family(cfg)

    def prefill_step(params, batch, cache):
        if cfg.family == "whisper":
            return fam.prefill(cfg, params, batch, cache)
        return fam.prefill(cfg, params, batch["tokens"], cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    fam = get_family(cfg)

    def decode_step(params, cache, tokens):
        return fam.decode_step(cfg, params, cache, tokens)

    return decode_step
