"""Post-partitioning HLO analysis: loop-aware flops / bytes / collectives.

Parses ``compiled.as_text()`` (per-device SPMD module).  XLA's own
``cost_analysis()`` counts a ``while`` body ONCE, so anything under a
``lax.scan`` (layer stacks, CE chunks, blocked attention) is undercounted by
its trip count.  ``analyze_module`` walks the computation call graph,
multiplies loop bodies by their trip counts (parsed from the loop condition's
comparison constant), and reports:

  * dot/convolution FLOPs (the >99% term for transformer workloads),
  * HBM traffic proxy: every top-level instruction materializes its result
    at a fusion boundary -> one write + (at least) one read per tensor:
    bytes = 2 x result bytes, summed over non-trivial top-level ops x trips
    (operands are NOT summed per-consumer — that would multi-count tensors
    XLA keeps in registers/VMEM across consumers),
  * per-device ICI link bytes for collectives with ring accounting:

    all-reduce       2 * size * (g-1)/g     (reduce-scatter + all-gather)
    all-gather       out_size * (g-1)/g
    reduce-scatter   in_size  * (g-1)/g     (in = out * g)
    all-to-all       size * (g-1)/g
    collective-permute  size

where g = replica-group size parsed from the op attributes.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))          # [num_groups, group_size]<=[N]
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0]
        ids = [x for x in first.replace("{", "").split(",") if x.strip()]
        if ids:
            return len(ids)
    return default


# ---------------------------------------------------------------------------
# loop-aware module analysis
# ---------------------------------------------------------------------------
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)(?:\.clone)?\s*\((.*)\)\s*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?)|\w+\[\])\s*"
    r"([\w\-]+)\((.*)$")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

# ops whose operands/results cross fusion boundaries (HBM traffic proxy)
_TRAFFIC_OPS = {
    "fusion", "dot", "convolution", "all-reduce", "all-gather",
    "reduce-scatter", "all-to-all", "collective-permute", "dynamic-slice",
    "dynamic-update-slice", "slice", "concatenate", "pad", "transpose",
    "reshape", "copy", "convert", "broadcast", "reduce", "sort", "gather",
    "scatter", "iota", "rng-bit-generator", "select-and-scatter", "custom-call",
}
_SKIP_OPERAND_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                     "bitcast", "while", "call", "conditional", "after-all"}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


@dataclass
class _Instr:
    name: str
    rtype: str
    op: str
    rest: str
    root: bool = False


def _parse_computations(text: str) -> Dict[str, List[_Instr]]:
    comps: Dict[str, List[_Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{"):
                m = _COMP_HEAD_RE.match(line.strip())
                if m:
                    cur = m.group(1)
                    comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(_Instr(m.group(1), m.group(2), m.group(3),
                                     m.group(4),
                                     root=line.lstrip().startswith("ROOT")))
    return comps


def _dims(shape_text: str) -> List[List[int]]:
    return [[int(d) for d in dims.split(",") if d] if dims else []
            for _, dims in _SHAPE_RE.findall(shape_text)]


def _dot_flops(instr: _Instr, sym: Dict[str, str]) -> float:
    res_dims = _dims(instr.rtype)
    if not res_dims:
        return 0.0
    res_elems = 1
    for d in res_dims[0]:
        res_elems *= d
    m = _CONTRACT_RE.search(instr.rest)
    operands = _OPERAND_RE.findall(instr.rest.split(")")[0])
    contract = 1
    if m and operands:
        lhs_type = sym.get(operands[0], "")
        lhs_dims = _dims(lhs_type)
        if lhs_dims:
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(lhs_dims[0]):
                    contract *= lhs_dims[0][idx]
    return 2.0 * res_elems * contract


def _trip_count(comp: List[_Instr]) -> int:
    """Trip count of a scan-style loop: resolve the constant operand of the
    condition's ROOT compare (possibly via a wrapped-compare fusion)."""
    by_name = {i.name: i for i in comp}
    consts = {i.name: int(m.group(1))
              for i in comp
              for m in [_CONST_RE.search(i.op + "(" + i.rest)]
              if i.op == "constant" and m}
    root = next((i for i in comp if i.root), comp[-1] if comp else None)
    if root is not None:
        vals = [consts[o] for o in _OPERAND_RE.findall(root.rest)
                if o in consts]
        if vals:
            return max(max(vals), 1)
    # fallback: max constant anywhere in the condition
    return max([1] + list(consts.values()))


@dataclass
class ModuleCosts:
    flops: float = 0.0
    bytes: float = 0.0
    link_bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)


def analyze_module(text: str, n_devices: int) -> ModuleCosts:
    comps = _parse_computations(text)
    syms = {cname: {i.name: i.rtype for i in instrs}
            for cname, instrs in comps.items()}
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEAD_RE.match(line.replace("ENTRY ", "").strip())
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: last computation
        entry = list(comps)[-1] if comps else None
    out = ModuleCosts()
    coll = defaultdict(lambda: {"count": 0.0, "bytes": 0.0, "link_bytes": 0.0})
    memo: Dict[str, Tuple[float, float, float, Dict]] = {}

    def comp_cost(cname: str) -> Tuple[float, float, float, Dict]:
        """(flops, bytes, link_bytes, coll dict) for one execution."""
        if cname in memo:
            return memo[cname]
        memo[cname] = (0.0, 0.0, 0.0, {})          # cycle guard
        fl = by = lk = 0.0
        cc: Dict[str, Dict[str, float]] = defaultdict(
            lambda: {"count": 0.0, "bytes": 0.0, "link_bytes": 0.0})
        sym = syms.get(cname, {})
        for ins in comps.get(cname, []):
            op = ins.op
            if op == "while":
                m = _COND_BODY_RE.search(ins.rest)
                if m:
                    cond, body = m.group(1), m.group(2)
                    trips = _trip_count(comps.get(cond, []))
                    bf, bb, bl, bc = comp_cost(body)
                    fl += trips * bf
                    by += trips * bb
                    lk += trips * bl
                    for k, v in bc.items():
                        cc[k]["count"] += trips * v["count"]
                        cc[k]["bytes"] += trips * v["bytes"]
                        cc[k]["link_bytes"] += trips * v["link_bytes"]
                continue
            if op == "call":
                m = _TO_APPLY_RE.search(ins.rest)
                if m:
                    bf, bb, bl, bc = comp_cost(m.group(1))
                    fl += bf; by += bb; lk += bl
                    for k, v in bc.items():
                        for kk in v:
                            cc[k][kk] += v[kk]
                continue
            if op == "conditional":
                m = _BRANCHES_RE.search(ins.rest)
                if m:
                    branches = _OPERAND_RE.findall(m.group(1))
                    costs = [comp_cost(b) for b in branches]
                    if costs:
                        bf, bb, bl, bc = max(costs, key=lambda c: c[0] + c[1])
                        fl += bf; by += bb; lk += bl
                continue
            if op == "fusion":
                m = _CALLS_RE.search(ins.rest)
                if m:
                    bf, _, _, _ = comp_cost(m.group(1))   # dots inside fusions
                    fl += bf
            if op == "dot" or op == "convolution":
                fl += _dot_flops(ins, sym)
            if op in COLLECTIVE_OPS or (op.endswith("-start") and
                                        op[:-6] in COLLECTIVE_OPS):
                kind = op[:-6] if op.endswith("-start") else op
                size = _shape_bytes(ins.rtype)
                g = _group_size(ins.rest, n_devices)
                ring = (g - 1) / g if g > 1 else 0.0
                if kind == "all-reduce":
                    link = 2 * size * ring
                elif kind == "all-gather":
                    link = size * ring
                elif kind == "reduce-scatter":
                    link = size * g * ring
                elif kind == "all-to-all":
                    link = size * ring
                else:
                    link = size
                lk += link
                cc[kind]["count"] += 1
                cc[kind]["bytes"] += size
                cc[kind]["link_bytes"] += link
            if op in _TRAFFIC_OPS:
                by += 2 * _shape_bytes(ins.rtype)
        memo[cname] = (fl, by, lk, dict(cc))
        return memo[cname]

    fl, by, lk, cc = comp_cost(entry) if entry else (0, 0, 0, {})
    out.flops, out.bytes, out.link_bytes = fl, by, lk
    out.collectives = {k: {kk: round(vv, 1) for kk, vv in v.items()}
                       for k, v in cc.items()}
    return out


def collective_stats(hlo_text: str, n_devices: int) -> Dict[str, Dict[str, float]]:
    """Per-op-kind: count, result bytes, per-device link bytes."""
    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "bytes": 0.0, "link_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_text, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_text)
        g = _group_size(line, n_devices)
        ring = (g - 1) / g if g > 1 else 0.0
        if kind == "all-reduce":
            link = 2 * size * ring
        elif kind == "all-gather":
            link = size * ring
        elif kind == "reduce-scatter":
            link = size * g * ring
        elif kind == "all-to-all":
            link = size * ring
        else:  # collective-permute
            link = size
        d = out[kind]
        d["count"] += 1
        d["bytes"] += size
        d["link_bytes"] += link
    return dict(out)


def total_link_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return sum(v["link_bytes"] for v in stats.values())


def schedule_summary(stats: Dict[str, Dict[str, float]]) -> str:
    parts = [f"{k}x{int(v['count'])}({v['link_bytes']/1e6:.1f}MB)"
             for k, v in sorted(stats.items())]
    return " ".join(parts) if parts else "none"
