"""ShapeDtypeStruct stand-ins for every model input — the dry-run contract.

No device allocation: params via jax.eval_shape over init, batches as
ShapeDtypeStructs, caches via eval_shape over init_cache.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.models.base import ModelConfig, abstract_params, get_family

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "whisper":
        return {"frames": _sds((b, cfg.enc_seq, cfg.d_model), cfg.jdtype),
                "tokens": _sds((b, s), I32), "labels": _sds((b, s), I32)}
    if cfg.family == "vlm":
        s_txt = s - cfg.n_patches
        return {"patches": _sds((b, cfg.n_patches, cfg.frontend_dim), cfg.jdtype),
                "tokens": _sds((b, s_txt), I32), "labels": _sds((b, s), I32)}
    return {"tokens": _sds((b, s), I32), "labels": _sds((b, s), I32)}


def batch_axes(cfg: ModelConfig, batch: Dict[str, Any]) -> Dict[str, Tuple]:
    return {k: ("batch",) + (None,) * (v.ndim - 1) for k, v in batch.items()}


def prefill_input_specs(cfg: ModelConfig, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    fam = get_family(cfg)
    cache = jax.eval_shape(lambda: fam.init_cache(cfg, b, s))
    if cfg.family == "whisper":
        batch = {"frames": _sds((b, cfg.enc_seq, cfg.d_model), cfg.jdtype),
                 "tokens": _sds((b, s), I32)}
    else:
        batch = {"tokens": _sds((b, s), I32)}
    return batch, cache


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    fam = get_family(cfg)
    cache = jax.eval_shape(lambda: fam.init_cache(cfg, b, s))
    tokens = _sds((b, 1), I32)
    return cache, tokens


def abstract_opt_state(cfg: ModelConfig, optimizer) -> Any:
    params = abstract_params(cfg)
    return jax.eval_shape(optimizer.init, params)


def opt_state_axes(cfg: ModelConfig, optimizer) -> Any:
    """Optimizer-state logical axes: m/v mirror the param axes; step=None."""
    fam = get_family(cfg)
    axes = fam.param_axes(cfg)
    state = abstract_opt_state(cfg, optimizer)
    is_axes = lambda x: x is None or (isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x))

    def mirror(sub):
        if isinstance(sub, dict) and "step" in sub:
            pass
        return sub

    out = {}
    for k, v in state.items():
        if k == "step":
            out[k] = None
        else:
            out[k] = axes
    return out
