"""Serving driver: continuous batching engine (+ optional MCTS decoding).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --requests 8 --max-new 16
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke --mcts
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.base import count_params, get_family
from repro.serving.engine import EngineConfig, Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--mcts", action="store_true")
    ap.add_argument("--mcts-budget", type=int, default=16)
    ap.add_argument("--mcts-lanes", type=int, default=2)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("whisper",):
        raise SystemExit("serve driver targets decoder-only archs; "
                         "whisper decoding runs via examples/")
    fam = get_family(cfg)
    params = fam.init(cfg, jax.random.key(0))
    print(f"arch={cfg.name} params={count_params(params):,}")
    rng = np.random.default_rng(0)

    if args.mcts:
        from repro.serving.mcts_decode import MCTSDecodeConfig, mcts_decode
        prompt = rng.integers(1, cfg.vocab_size, size=args.prompt_len)
        dcfg = MCTSDecodeConfig(budget=args.mcts_budget, lanes=args.mcts_lanes)
        t0 = time.time()
        toks = mcts_decode(cfg, params, prompt, args.max_new, dcfg)
        dt = time.time() - t0
        print(f"mcts-decode: {toks}")
        print(f"{args.max_new} tokens in {dt:.1f}s "
              f"({args.max_new * dcfg.budget} playouts, "
              f"{args.max_new * dcfg.budget / dt:.1f} playouts/s)")
        return

    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=args.max_batch, max_seq=args.max_seq))
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, args.prompt_len + 1))
        eng.submit(Request(uid=i,
                           prompt=rng.integers(1, cfg.vocab_size, size=plen),
                           max_new_tokens=args.max_new))
    out = eng.run_until_drained()
    dt = time.time() - t0
    print(f"served {args.requests} requests, {out['tokens']} tokens "
          f"in {dt:.1f}s ({out['tokens']/dt:,.1f} tok/s, "
          f"{out['steps']} engine steps)")
    print(f"latency p50={out['latency_p50']:.3f}s "
          f"p95={out['latency_p95']:.3f}s")
    for k, v in sorted(out["stats"].items()):
        print(f"  {k}={v:.4g}")


if __name__ == "__main__":
    main()
