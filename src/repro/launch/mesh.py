"""Production mesh builders.

A function (not a module constant) so importing never touches jax device
state; the dry-run sets XLA_FLAGS for 512 host devices *before* first jax
init, real launches use the actual slice topology.
"""
from __future__ import annotations

import jax

from repro.parallel.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_search_mesh(n: int = 0):
    """1-D mesh over ``n`` (default: all) devices, axis name "batch" — the
    mesh shape `shard_search_batch` partitions batched multi-root search
    over (DESIGN.md §9)."""
    n = n or len(jax.devices())
    return make_mesh((n,), ("batch",))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return make_mesh((data, model), ("data", "model"))
