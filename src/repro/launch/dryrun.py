import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds abstract params/optimizer/batch/cache
(ShapeDtypeStruct only — no allocation), resolves shardings from the logical
rules table, lowers the real step function with pjit in/out shardings,
compiles it AOT, and records:

  * memory_analysis()  — per-device bytes (proves it fits),
  * cost_analysis()    — HLO FLOPs / bytes accessed (roofline numerator),
  * collective schedule + per-device link bytes parsed from the partitioned
    HLO (roofline collective term).

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out experiments/dryrun.json
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, cell_enabled
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (abstract_opt_state, batch_axes,
                                decode_input_specs, opt_state_axes,
                                prefill_input_specs, train_input_specs)
from repro.launch.steps import (make_decode_step, make_grad_accum_train_step,
                                make_prefill_step, make_train_step)
from repro.models.base import abstract_params, active_param_count, count_params, get_family
from repro.optim import adamw
from repro.optim.schedules import constant
from repro.parallel.sharding import DEFAULT_RULES, active_rules, make_shardings

# Gradient-accumulation microbatches for the train_4k cells: sized so the
# per-device remat carries (L x B_local/micro x S x D bf16, plus XLA's f32
# convert copy) fit 16 GB HBM alongside the sharded optimizer state.
# Per-arch sharding-rule overrides (hillclimb S1): archs whose head count
# can't split the model axis (smollm 9H, qwen2 14H/2kv, whisper 8H) otherwise
# run attention REPLICATED over model (useful-flops ratio 0.04-0.16). Letting
# the batch claim (data, model) makes attention shard-local; weights are
# all-gathered instead (cheap at these sizes).
ARCH_RULES_EXTRA = {
    "smollm-135m": {"batch": (("pod", "data", "model"), ("data", "model"),
                              ("pod", "data"), ("data",))},
    "qwen2-0.5b": {"batch": (("pod", "data", "model"), ("data", "model"),
                             ("pod", "data"), ("data",))},
    "whisper-base": {"batch": (("pod", "data", "model"), ("data", "model"),
                               ("pod", "data"), ("data",))},
    "minicpm-2b": {"batch": (("pod", "data", "model"), ("data", "model"),
                             ("pod", "data"), ("data",))},
}

# Hillclimb R1: decode/prefill cells for models whose weights fit replicated
# (after model-axis TP) drop FSDP storage sharding — training's embed->data
# sharding makes every decode step all-gather the weights it touches (rwkv6
# decode_32k measured collective-bound 600x over compute). ~16B+ models keep
# FSDP (weights don't fit replicated).
FSDP_ALWAYS = {"grok-1-314b", "deepseek-v2-lite-16b"}

TRAIN_MICROBATCH = {
    "grok-1-314b": 8,
    "deepseek-v2-lite-16b": 4,
    "zamba2-1.2b": 4,
    # minicpm: no microbatching — its S1 batch-over-(data,model) override
    # shards B=256 across all 256 chips (1 row/device; carries ~1 GB),
    # and microbatch slices of 128 would break the 256-way divisibility.
    "stablelm-3b": 2,
    "internvl2-2b": 2,
    "rwkv6-1.6b": 2,
}


def _mem_dict(mem) -> Dict[str, float]:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        try:
            out[k] = float(getattr(mem, k))
        except Exception:
            pass
    return out


def _cost_dict(cost) -> Dict[str, float]:
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals", "utilization operand 0"):
        if k in cost:
            out[k.replace(" ", "_")] = float(cost[k])
    return out


def lower_cell(arch: str, shape_name: str, mesh, rules=None) -> Any:
    """Build + lower the cell's step function. Returns (lowered, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    fam = get_family(cfg)
    if rules is None:
        rules = dict(DEFAULT_RULES)
        rules.update(ARCH_RULES_EXTRA.get(arch, {}))
        if SHAPES[shape_name].kind != "train" and arch not in FSDP_ALWAYS:
            rules["embed"] = ()          # replicate weights for inference

    params_abs = abstract_params(cfg)
    axes = fam.param_axes(cfg)
    pshard = make_shardings(axes, params_abs, mesh)
    meta = {"params": count_params(params_abs),
            "active_params": active_param_count(cfg)}

    if shape.kind == "train":
        opt = adamw()
        opt_abs = abstract_opt_state(cfg, opt)
        oshard = make_shardings(opt_state_axes(cfg, opt), opt_abs, mesh, rules)
        batch = train_input_specs(cfg, shape)
        micro = TRAIN_MICROBATCH.get(arch, 1)
        if micro > 1:
            batch = {k: jax.ShapeDtypeStruct(
                (micro, v.shape[0] // micro) + v.shape[1:], v.dtype)
                for k, v in batch.items()}
            baxes = {k: (None, "batch") + (None,) * (v.ndim - 2)
                     for k, v in batch.items()}
            step = make_grad_accum_train_step(cfg, opt, constant(1e-4), micro)
        else:
            baxes = batch_axes(cfg, batch)
            step = make_train_step(cfg, opt, constant(1e-4))
        bshard = make_shardings(baxes, batch, mesh, rules)
        jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
        with mesh, active_rules(rules):
            lowered = jitted.lower(params_abs, opt_abs, batch)
        meta["tokens"] = shape.global_batch * shape.seq_len
        meta["microbatches"] = micro
    elif shape.kind == "prefill":
        batch, cache = prefill_input_specs(cfg, shape)
        cshard = make_shardings(fam.cache_axes(cfg), cache, mesh, rules)
        bshard = make_shardings(batch_axes(cfg, batch), batch, mesh, rules)
        step = make_prefill_step(cfg)
        jitted = jax.jit(step, in_shardings=(pshard, bshard, cshard),
                         out_shardings=(None, cshard), donate_argnums=(2,))
        with mesh, active_rules(rules):
            lowered = jitted.lower(params_abs, batch, cache)
        meta["tokens"] = shape.global_batch * shape.seq_len
    else:  # decode
        cache, tokens = decode_input_specs(cfg, shape)
        cshard = make_shardings(fam.cache_axes(cfg), cache, mesh, rules)
        tshard = make_shardings({"t": ("batch", None)}, {"t": tokens}, mesh, rules)["t"]
        step = make_decode_step(cfg)
        jitted = jax.jit(step, in_shardings=(pshard, cshard, tshard),
                         out_shardings=(None, cshard), donate_argnums=(1,))
        with mesh, active_rules(rules):
            lowered = jitted.lower(params_abs, cache, tokens)
        meta["tokens"] = shape.global_batch          # one token per sequence
    return lowered, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules=None, verbose: bool = True) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if multi_pod else "16x16",
                           "devices": n_dev}
    t0 = time.time()
    try:
        lowered, meta = lower_cell(arch, shape_name, mesh, rules)
        rec.update(meta)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rec["lower_s"] = round(t1 - t0, 1)
        rec["compile_s"] = round(t2 - t1, 1)
        rec["memory"] = _mem_dict(compiled.memory_analysis())
        rec["cost"] = _cost_dict(compiled.cost_analysis())
        text = compiled.as_text()
        costs = hlo_analysis.analyze_module(text, n_dev)   # loop-aware
        rec["flops_per_device"] = costs.flops
        rec["hbm_bytes_per_device"] = costs.bytes
        rec["link_bytes_per_device"] = costs.link_bytes
        rec["collectives"] = costs.collectives
        rec["collective_schedule"] = hlo_analysis.schedule_summary(costs.collectives)
        rec["status"] = "ok"
        if verbose:
            mem = rec["memory"].get("temp_size_in_bytes", 0) / 1e9
            arg = rec["memory"].get("argument_size_in_bytes", 0) / 1e9
            print(f"[ok] {arch:>22s} {shape_name:>12s} {rec['mesh']:>7s} "
                  f"lower {rec['lower_s']:6.1f}s compile {rec['compile_s']:6.1f}s "
                  f"args {arg:6.2f}GB temp {mem:6.2f}GB flops/dev {costs.flops:.3e} "
                  f"hbm/dev {costs.bytes/1e9:7.1f}GB link/dev {costs.link_bytes/1e6:8.1f}MB "
                  f"| {rec['collective_schedule'][:70]}")
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[ERR] {arch} {shape_name} {rec['mesh']}: {rec['error'][:200]}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                if not cell_enabled(arch, shape):
                    records.append({"arch": arch, "shape": shape,
                                    "mesh": "2x16x16" if mp else "16x16",
                                    "status": "skipped",
                                    "reason": "full attention is quadratic at 500k; "
                                              "run only for SSM/hybrid archs"})
                    print(f"[skip] {arch} {shape}")
                    continue
                records.append(run_cell(arch, shape, mp))
    n_ok = sum(r["status"] == "ok" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"\ndry-run complete: {n_ok} ok, {n_err} errors, "
          f"{sum(r['status'] == 'skipped' for r in records)} skipped")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
