"""Training driver: data pipeline + optimizer + FT loop + checkpoints.

CPU-runnable end-to-end with ``--smoke`` (reduced same-family configs); the
full configs are exercised via launch/dryrun.py on the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, Prefetcher, make_batch_iterator
from repro.launch.steps import make_train_step
from repro.models.base import count_params, get_family
from repro.optim import adamw, lion
from repro.optim.schedules import cosine, wsd
from repro.runtime.ft import FTConfig, TrainerLoop


def build(arch: str, smoke: bool, batch: int, seq: int, lr: float,
          steps: int, optimizer: str = "adamw"):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    fam = get_family(cfg)
    opt = {"adamw": adamw, "lion": lion}[optimizer]()
    # MiniCPM pairs with WSD (its paper's contribution); others cosine
    sched = (wsd(lr, warmup=max(steps // 20, 1), stable=steps // 2,
                 decay=max(steps // 3, 1))
             if arch.startswith("minicpm") else
             cosine(lr, warmup=max(steps // 20, 1), total=steps))
    step_fn = jax.jit(make_train_step(cfg, opt, sched), donate_argnums=(0, 1))
    params = fam.init(cfg, jax.random.key(0))
    opt_state = opt.init(params)
    dcfg = DataConfig(seed=0, batch_size=batch, seq_len=seq)
    return cfg, step_fn, params, opt_state, dcfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg, step_fn, params, opt_state, dcfg = build(
        args.arch, args.smoke, args.batch, args.seq, args.lr, args.steps,
        args.optimizer)
    print(f"arch={cfg.name} params={count_params(params):,} "
          f"batch={args.batch}x{args.seq}")

    ft = FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    loop = TrainerLoop(
        step_fn, params, opt_state,
        lambda start: Prefetcher(make_batch_iterator(cfg, dcfg, start)), ft)
    if loop.try_restore():
        print(f"restored from step {loop.step}")

    t0 = time.time()
    last = t0
    start = loop.step
    while loop.step < args.steps:
        n = min(args.log_every, args.steps - loop.step)
        out = loop.run(n)
        now = time.time()
        tput = n * args.batch * args.seq / (now - last)
        last = now
        print(f"step {loop.step:5d} loss {out['losses'][-1]:.4f} "
              f"tok/s {tput:,.0f}")
    wall = time.time() - t0
    print(f"done: {loop.step - start} steps in {wall:.1f}s; "
          f"final loss {loop.history[-1]:.4f}")


if __name__ == "__main__":
    main()
