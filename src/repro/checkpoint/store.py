"""Sharded, async, atomic checkpointing with elastic restore.

Layout:  <dir>/step_<N>/{manifest.json, leaf_<i>.npy..., COMMITTED}

* save is atomic: leaves + manifest land in a tmp dir, then a single rename +
  COMMITTED marker; a crash mid-save never corrupts the latest checkpoint;
* async: the device->host transfer happens on the caller thread (cheap), the
  file writes on a background thread; ``wait()`` joins before the next save;
* elastic restore: leaves are re-sharded to whatever mesh/sharding the
  restoring job passes (``jax.device_put`` with the new sharding), so a job
  restarted at a different world size resumes from the same step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Optional

import jax
import ml_dtypes
import numpy as np

COMMITTED = "COMMITTED"

# numpy can't serialize bfloat16 natively; round-trip via a same-width view
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_savable(arr: np.ndarray):
    name = arr.dtype.name if hasattr(arr.dtype, "name") else str(arr.dtype)
    if name in _VIEW_AS:
        return arr.view(_VIEW_AS[name]), name
    return arr, name


def _from_savable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_AS:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _leaf_paths(d: str, n: int):
    return [os.path.join(d, f"leaf_{i}.npy") for i in range(n)]


def save(ckpt_dir: str, step: int, tree: Any, *, asynchronous: bool = False,
         keep: int = 3) -> Optional[threading.Thread]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host_leaves = [np.asarray(x) for x in leaves]    # device -> host now
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"

    def _write():
        os.makedirs(tmp_dir, exist_ok=True)
        dtype_names = []
        for p, arr in zip(_leaf_paths(tmp_dir, len(host_leaves)), host_leaves):
            savable, name = _to_savable(arr)
            dtype_names.append(name)
            np.save(p, savable)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(host_leaves),
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": dtype_names,
        }
        with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(step_dir):
            shutil.rmtree(step_dir)
        os.rename(tmp_dir, step_dir)
        with open(os.path.join(step_dir, COMMITTED), "w") as f:
            f.write("ok")
        _gc(ckpt_dir, keep)

    if asynchronous:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(_committed_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
    # Reap debris from crashed saves (single-writer contract: _gc runs after
    # the current save has committed, so anything else is dead):
    #  * step_*.tmp    — killed before the atomic rename;
    #  * uncommitted step dirs — killed between the rename and the COMMITTED
    #    marker; never observable via latest_step/restore, so safe to drop.
    committed = set(steps)
    for name in os.listdir(ckpt_dir):
        path = os.path.join(ckpt_dir, name)
        if name.startswith("step_") and name.endswith(".tmp"):
            shutil.rmtree(path, ignore_errors=True)
        elif name.startswith("step_"):
            try:
                s = int(name[5:])
            except ValueError:
                continue
            if s not in committed:
                shutil.rmtree(path, ignore_errors=True)


def _committed_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, COMMITTED)):
                out.append(int(name[5:]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _committed_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optionally reshard each leaf
    onto ``shardings`` (elastic restart at a different mesh)."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.exists(os.path.join(step_dir, COMMITTED)):
        raise FileNotFoundError(f"no committed checkpoint at {step_dir}")
    leaves, treedef = jax.tree_util.tree_flatten(like)
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint at {step_dir} has {manifest['n_leaves']} leaves but "
            f"the restore template has {len(leaves)} — structures differ")
    arrays = [_from_savable(np.load(p), dt) for p, dt in
              zip(_leaf_paths(step_dir, len(leaves)), manifest["dtypes"])]
    for a, l in zip(arrays, leaves):
        if tuple(a.shape) != tuple(l.shape):
            raise ValueError(f"shape mismatch {a.shape} vs {l.shape}")
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(shardings)
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, shard_leaves)]
    return jax.tree_util.tree_unflatten(treedef, arrays)


class CheckpointManager:
    """Keeps at most one async save in flight; joins before the next one."""

    def __init__(self, ckpt_dir: str, keep: int = 3, every: int = 100):
        self.dir = ckpt_dir
        self.keep = keep
        self.every = every
        self._pending: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def maybe_save(self, step: int, tree: Any) -> bool:
        if step % self.every:
            return False
        self.wait()
        self._pending = save(self.dir, step, tree, asynchronous=True,
                             keep=self.keep)
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def latest(self) -> Optional[int]:
        return latest_step(self.dir)

    def restore_latest(self, like: Any, shardings: Any = None):
        step = self.latest()
        if step is None:
            return None, None
        return step, restore(self.dir, step, like, shardings)
