"""Fused search-wave entry points: ref / Pallas dispatch + arena plumbing.

``core.stages.mega_round`` / ``mega_tick`` land here.  The implementation
is chosen by ``SearchParams.kernels`` ("pallas" on TPU under "auto"),
overridable per-call for tests (``impl=``, ``interpret=`` to run the
Pallas kernels on CPU via the interpreter).

This module owns the arena <-> kernel-plane packing:

* 1-D arena planes (visits/value/in-flight/terminal/free_list) ride as
  ``[N, 1]`` VMEM blocks; 2-D planes (children/prior) as ``[N, A]``.  The
  in-flight slot carries ``tree.vloss`` ("loss" mode) or ``tree.unobs``
  ("wu" mode, WU-UCT O counts) — see ``kernel.WaveCfg``;
* ``next_free`` / ``free_top`` / wave validity ride in one ``[1, 4]``
  scalar word;
* the kernel mutates visits/value/vloss/prior/children in place
  (input/output aliased) and emits the Select buffers + structural Expand
  result; parent/action pointers, the free-list bookkeeping, and the path
  append are cheap scatter/where updates applied here, outside the launch
  (they are not on the per-level critical path the fusion removes).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.arena import UNEXPANDED, TreeArena
from repro.kernels.search_wave import kernel as K
from repro.kernels.search_wave import ref


def _cfg(tree: TreeArena, sp, lanes: int) -> K.WaveCfg:
    return K.WaveCfg(n=tree.max_nodes, a=tree.num_actions, lanes=lanes,
                     path_len=sp.path_len, max_depth=sp.max_depth,
                     cp=float(sp.cp), vl_weight=float(sp.vl_weight),
                     puct=bool(sp.puct), wu=bool(getattr(sp, "wu", False)),
                     running=bool(getattr(sp, "running", False)))


def _infl_field(sp) -> str:
    """The arena field backing the kernel's in-flight plane slot."""
    return "unobs" if getattr(sp, "wu", False) else "vloss"


def _planes(tree: TreeArena, sp, wave_valid):
    col = lambda x, dt: x.astype(dt).reshape(-1, 1)
    scal = jnp.stack([tree.next_free.astype(jnp.int32),
                      tree.free_top.astype(jnp.int32),
                      jnp.asarray(wave_valid).astype(jnp.int32).reshape(()),
                      jnp.int32(0)]).reshape(1, 4)
    return {
        "visits": col(tree.visits, jnp.int32),
        "value": col(tree.value, jnp.float32),
        # the mode's in-flight counter plane (WaveCfg.wu docstring): vloss
        # in "loss" mode, the WU-UCT unobs counts in "wu" mode
        "infl": col(getattr(tree, _infl_field(sp)), jnp.int32),
        "prior": tree.prior.astype(jnp.float32),
        "children": tree.children.astype(jnp.int32),
        "terminal": col(tree.terminal, jnp.int32),
        "free_list": col(tree.free_list, jnp.int32),
        "scal": scal,
    }


def _pb(po, num_actions: int):
    """Pack a Playout->Backup buffer for the kernel (6 2-D operands)."""
    return (po["path"].astype(jnp.int32),
            po["value"].astype(jnp.float32)[:, None],
            po["priors"].astype(jnp.float32),
            po["node"].astype(jnp.int32)[:, None],
            po["is_new"].astype(jnp.int32)[:, None],
            po["valid"].astype(jnp.int32)[:, None])


def _empty_pb(sp, lanes: int, num_actions: int):
    from repro.core import stages as S
    return _pb(S.empty_playout(sp, lanes, num_actions), num_actions)


def _unpack_sel(s_leaf, s_depth, s_path, s_dup, valid):
    dup_w, dup_c = s_dup[:, 0] > 0, s_dup[:, 1] > 0
    return {"path": s_path, "leaf": s_leaf[:, 0], "depth": s_depth[:, 0],
            "valid": valid, "dup": dup_w | dup_c,
            "dup_within": dup_w, "dup_cross": dup_c}


def _apply_es(tree: TreeArena, sel_path, sel_depth, leafs,
              e_can, e_slot, e_new, valid):
    """Out-of-launch half of the structural expand: parent/action pointers,
    free-list bookkeeping, path append.  Mirrors ``ref.expand_wave_struct``
    exactly (``new`` already carries the max_nodes drop sentinel)."""
    can = e_can[:, 0] > 0
    slot = e_slot[:, 0]
    new_s = e_new[:, 0]
    lanes = can.shape[0]
    nf0, ft0 = tree.next_free, tree.free_top
    r_total = can.sum().astype(jnp.int32)
    pops = jnp.minimum(r_total, ft0)
    rows = jnp.arange(lanes)
    path = sel_path.at[rows, sel_depth + 1].set(
        jnp.where(can, new_s, UNEXPANDED))
    tree = tree.replace(
        parent=tree.parent.at[new_s].set(leafs, mode="drop"),
        action=tree.action.at[new_s].set(slot, mode="drop"),
        next_free=nf0 + (r_total - pops),
        free_top=ft0 - pops)
    es = {"leaf": leafs, "slot": slot, "new": new_s, "can": can,
          "path": path, "node": jnp.where(can, new_s, leafs),
          "valid": valid}
    return tree, es


def _resolve(sp, impl):
    return impl if impl is not None else sp.resolved_kernels


def tree_round(tree: TreeArena, domain, sp, lanes: int, valid, rng, *,
               impl=None, interpret=False):
    """One fused tree-parallel round.  Pallas path: launch 1 is
    Select→Expand(structural), then the out-of-launch domain finish +
    playout, then launch 2 is Backup.  Returns ``(tree, sel)``."""
    if _resolve(sp, impl) != "pallas":
        return ref.tree_round(tree, domain, sp, lanes, valid, rng)
    from repro.core import stages as S
    cfg = _cfg(tree, sp, lanes)
    wv = jnp.asarray(valid, bool).all()       # kernel waves are all-or-none
    p = _planes(tree, sp, wv)
    (infl, children, s_leaf, s_depth, s_path, s_dup,
     e_can, e_slot, e_new) = K.se_call(
        cfg, p["infl"], p["children"], p["visits"], p["value"], p["prior"],
        p["terminal"], p["free_list"], p["scal"], interpret=interpret)
    valid_vec = jnp.broadcast_to(wv, (lanes,))
    sel = _unpack_sel(s_leaf, s_depth, s_path, s_dup, valid_vec)
    tree = tree.replace(children=children,
                        **{_infl_field(sp): infl[:, 0]})
    tree, es = _apply_es(tree, sel["path"], sel["depth"], sel["leaf"],
                         e_can, e_slot, e_new, valid_vec)
    tree, exp = ref.finish_expand(tree, domain, es)
    po = S.playout_wave(domain, sp, exp, rng)
    p2 = _planes(tree, sp, wv)
    visits, value, infl, prior = K.b_call(
        cfg, p2["visits"], p2["value"], p2["infl"], p2["prior"],
        _pb(po, cfg.a), interpret=interpret)
    tree = tree.replace(visits=visits[:, 0], value=value[:, 0],
                        prior=prior, **{_infl_field(sp): infl[:, 0]})
    return tree, sel


def pipeline_tick(tree: TreeArena, domain, sp, lanes: int, wave_valid,
                  buf_se, buf_ep, buf_pb, rng, *, impl=None,
                  interpret=False):
    """One fused pipeline tick: a single Backup→Expand→Select launch over
    the arena planes, plus the out-of-launch playout and expand finish.
    Returns ``(tree, new_se, new_ep, new_pb)``."""
    if _resolve(sp, impl) != "pallas":
        return ref.pipeline_tick(tree, domain, sp, lanes, wave_valid,
                                 buf_se, buf_ep, buf_pb, rng)
    from repro.core import stages as S
    cfg = _cfg(tree, sp, lanes)
    p = _planes(tree, sp, wave_valid)
    se_leaf = buf_se["leaf"].astype(jnp.int32)[:, None]
    se_valid = buf_se["valid"].astype(jnp.int32)[:, None]
    (visits, value, infl, prior, children,
     s_leaf, s_depth, s_path, s_dup, e_can, e_slot, e_new) = K.bes_call(
        cfg, p["visits"], p["value"], p["infl"], p["prior"], p["children"],
        p["terminal"], p["free_list"], p["scal"], se_leaf, se_valid,
        _pb(buf_pb, cfg.a), interpret=interpret)
    tree = tree.replace(visits=visits[:, 0], value=value[:, 0],
                        prior=prior, children=children,
                        **{_infl_field(sp): infl[:, 0]})
    tree, es = _apply_es(tree, buf_se["path"], buf_se["depth"],
                         buf_se["leaf"], e_can, e_slot, e_new,
                         buf_se["valid"])
    new_pb = S.playout_wave(domain, sp, buf_ep, rng)
    tree, new_ep = ref.finish_expand(tree, domain, es)
    valid_vec = jnp.broadcast_to(jnp.asarray(wave_valid, bool), (lanes,))
    new_se = _unpack_sel(s_leaf, s_depth, s_path, s_dup, valid_vec)
    return tree, new_se, new_ep, new_pb
