"""Pallas TPU fused search-wave megakernel (DESIGN.md §14).

One launch drives a whole wave against the VMEM-resident arena planes
instead of a kernel launch per tree level:

* ``se_call``  — Select(lockstep descent, every level in-kernel) →
  Expand(structural allocation) for the tree strategy's round.
* ``bes_call`` — Backup(wave t-3) → Expand(wave t-1, structural) →
  Select(wave t) for the pipeline tick: three stages, one launch.
* ``b_call``   — Backup alone (the tree round's second launch, after the
  out-of-kernel playout).

The *domain* half of Expand (model ``step``/``is_terminal``) cannot run
inside a kernel; the kernel emits the structural result (can/slot/new row
per lane) and ``ref.finish_expand`` completes state/terminal outside the
launch.  Running Select ahead of that finish is sound — see ``ref.py``.

Layout notes (guide: 1-D iota is unsupported on TPU — all index vectors
come from ``broadcasted_iota``; scalars ride in one ``[1, 4]`` SMEM word;
gathers/one-hot reductions use ``precision=HIGHEST`` dots so integer
planes round-trip exactly below 2^24).  Grid is () — every plane fits one
VMEM block at search-tree sizes; the mutable planes are input/output
aliased so the launch updates them in place.

Parity contract: phases mirror ``core.stages`` formula-for-formula
(including the 1e30 unvisited clamp, the -1e30 invalid mask, first-max
argmax tie-breaking, and the per-level own-virtual-loss exclusion), so the
launch is bit-for-bit equal to the lockstep path at ``lanes == 1`` and
integer-exact at any width; float backup sums may differ in the last ulp
at ``lanes > 1`` only where a node takes multiple same-wave contributions.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.parallel.compat import tpu_compiler_params

UNEXPANDED = -1
ROOT = 0
NEG_INF = -1e30          # python literal: jnp constants can't be captured
                         # inside a pallas kernel body
_HI = jax.lax.Precision.HIGHEST


@dataclasses.dataclass(frozen=True)
class WaveCfg:
    """Static shape/knob bundle threaded through the phase helpers.

    ``wu`` selects the in-flight statistics mode (DESIGN.md §15).  The
    launch carries ONE in-flight plane operand (the ``vloss``-named slot):
    ops.py stages ``tree.vloss`` there in "loss" mode and ``tree.unobs``
    (the WU-UCT O counts) in "wu" mode — increments fused into the descent
    /expand, decrements fused into backup, input/output-aliased either way.
    Only the scoring formula branches on ``wu``; the inactive plane is
    all-zeros and never enters the kernel.
    """
    n: int            # max_nodes
    a: int            # num_actions
    lanes: int
    path_len: int
    max_depth: int
    cp: float
    vl_weight: float
    puct: bool
    wu: bool = False
    running: bool = False   # within-level running assignment (DESIGN.md §16)


def _iota(rows: int, cols: int, dim: int):
    return jax.lax.broadcasted_iota(jnp.int32, (rows, cols), dim)


def _onehot(idx, n):
    """[K] i32 -> [K, n] f32 one-hot (0 where idx is out of range)."""
    k = idx.shape[0]
    return (_iota(k, n, 1) == idx[:, None]).astype(jnp.float32)


def _gather_vec(v, idx):
    """v [N] f32, idx [K] -> [K] f32 (exact for integer-valued v < 2^24)."""
    return jax.lax.dot_general(_onehot(idx, v.shape[0]), v[:, None],
                               (((1,), (0,)), ((), ())),
                               precision=_HI)[:, 0]


def _gather_rows(m, idx):
    """m [N, C] f32, idx [K] -> [K, C] f32."""
    return jax.lax.dot_general(_onehot(idx, m.shape[0]), m,
                               (((1,), (0,)), ((), ())), precision=_HI)


# ---------------------------------------------------------------------------
# phase helpers (operate on refs/values; mirror core.stages bit-for-bit)
# ---------------------------------------------------------------------------
def _backup_phase(cfg: WaveCfg, visits_ref, value_ref, vloss_ref, prior_ref,
                  pb_path, pb_value, pb_isnew, pb_node, pb_priors, pb_valid):
    l, p, n = cfg.lanes, cfg.path_len, cfg.n
    mask = (pb_path >= 0) & (pb_valid > 0)                 # [L, P]
    flat_idx = pb_path.reshape(l * p)
    flat_m = mask.reshape(l * p)
    oh = _onehot(flat_idx, n) * flat_m.reshape(l * p, 1).astype(jnp.float32)
    counts = oh.sum(axis=0)                                # [N] f32, exact
    vals = jnp.broadcast_to(pb_value.reshape(l, 1), (l, p)).reshape(l * p)
    vsum = jax.lax.dot_general(vals[None, :], oh,
                               (((1,), (0,)), ((), ())), precision=_HI)[0]
    visits_ref[...] = visits_ref[...] + counts[:, None].astype(jnp.int32)
    value_ref[...] = value_ref[...] + vsum[:, None]
    vloss_ref[...] = vloss_ref[...] - counts[:, None].astype(jnp.int32)
    # priors for freshly created nodes (distinct rows across lanes)
    pidx = jnp.where((pb_isnew > 0) & (pb_valid > 0), pb_node, n)[:, 0]
    ohp = _onehot(pidx, n)                                 # [L, N]
    written = ohp.sum(axis=0) > 0.5                        # [N]
    pnew = jax.lax.dot_general(ohp, pb_priors, (((0,), (0,)), ((), ())),
                               precision=_HI)              # [N, A]
    prior_ref[...] = jnp.where(written[:, None], pnew, prior_ref[...])


def _expand_phase(cfg: WaveCfg, children_ref, vloss_ref, terminal_v,
                  free_list_ref, nf0, ft0, leafs, valid):
    """Sequential-semantics structural expand: a fori over lanes reading the
    live children rows, exactly like scanning ``stages.expand_one``."""
    l, n, a = cfg.lanes, cfg.n, cfg.a
    cap0 = ft0 + (n - nf0)
    iota_a = _iota(1, a, 1)[0]

    def body(i, carry):
        r, can_acc, slot_acc, new_acc = carry
        leaf = jax.lax.dynamic_index_in_dim(leafs, i, keepdims=False)
        row = children_ref[pl.ds(leaf, 1), :][0]           # live row [A]
        free = row == UNEXPANDED
        has_slot = free.any()
        term = jax.lax.dynamic_index_in_dim(terminal_v, i, keepdims=False)
        lane_ok = jax.lax.dynamic_index_in_dim(valid, i, keepdims=False)
        can = lane_ok & has_slot & ~term & (r < cap0)
        slot = jnp.min(jnp.where(free, iota_a, a)).astype(jnp.int32)
        slot = jnp.minimum(slot, a - 1)
        pop_row = jnp.clip(ft0 - 1 - r, 0, n - 1)
        new = jnp.where(
            r < ft0,
            free_list_ref[pl.ds(pop_row, 1), :][0, 0],
            nf0 + (r - ft0)).astype(jnp.int32)
        # link the child + its in-flight virtual loss (row-granular stores)
        new_row = jnp.where((iota_a == slot) & can, new, row)
        children_ref[pl.ds(leaf, 1), :] = new_row[None, :]
        nc = jnp.clip(new, 0, n - 1)
        vrow = vloss_ref[pl.ds(nc, 1), :]
        vloss_ref[pl.ds(nc, 1), :] = vrow + jnp.where(can, 1, 0)
        r = r + can.astype(jnp.int32)
        can_acc = can_acc.at[i].set(can)
        slot_acc = slot_acc.at[i].set(slot)
        new_acc = new_acc.at[i].set(jnp.where(can, new, n))
        return r, can_acc, slot_acc, new_acc

    _, can, slot, new_s = jax.lax.fori_loop(
        0, l, body,
        (jnp.int32(0), jnp.zeros((l,), bool), jnp.zeros((l,), jnp.int32),
         jnp.zeros((l,), jnp.int32)))
    # terminal gather is done against leaf *indices* by the caller
    return can, slot, new_s


def _select_phase(cfg: WaveCfg, vloss_ref, visits_v, value_v, prior_v,
                  children_v, terminal_v, wave_valid):
    """Lockstep descent, every level in-kernel (mirrors
    ``stages.select_wave_fused``)."""
    l, n, a, p = cfg.lanes, cfg.n, cfg.a, cfg.path_len
    valid = jnp.broadcast_to(wave_valid > 0, (l,))
    vloss_pre = vloss_ref[...][:, 0]                       # pre-wave, for dup
    rv = vloss_ref[pl.ds(ROOT, 1), :]
    vloss_ref[pl.ds(ROOT, 1), :] = rv + valid.sum().astype(jnp.int32)

    def lane_active(node, depth):
        ch = _gather_rows(children_v, node)
        fully = (ch >= -0.5).all(axis=-1)                  # all children >= 0
        term = _gather_vec(terminal_v, node) > 0.5
        return fully & ~term & (depth < cfg.max_depth)

    node0 = jnp.zeros((l,), jnp.int32)
    depth0 = jnp.zeros((l,), jnp.int32)
    path0 = jnp.where(_iota(l, p, 1) == 0, ROOT, UNEXPANDED)
    active0 = valid & lane_active(node0, depth0)
    iota_a = _iota(l, a, 1)
    iota_p = _iota(l, p, 1)

    def body(_, c):
        node, depth, path, active = c
        chf = _gather_rows(children_v, node)               # [L, A] f32
        ch = chf.astype(jnp.int32)
        idx = jnp.maximum(ch, 0)
        vloss_v = vloss_ref[...][:, 0].astype(jnp.float32)
        own = active.astype(jnp.int32)
        cn = _gather_vec(visits_v, idx.reshape(-1)).reshape(l, a)
        cw = _gather_vec(value_v, idx.reshape(-1)).reshape(l, a)
        cvl = _gather_vec(vloss_v, idx.reshape(-1)).reshape(l, a)
        pn = (_gather_vec(visits_v, node) + _gather_vec(vloss_v, node)
              - own.astype(jnp.float32))
        pr = _gather_rows(prior_v, node) if cfg.puct else None
        # uct_scores, formula-for-formula (core.uct); in "wu" mode cvl holds
        # the gathered O counts — they widen exploration only, Q is computed
        # from completed statistics alone
        if cfg.running:
            # Running assignment (DESIGN.md §16): a sequential lane walk —
            # lane i scores with a running delta already incremented by the
            # picks of co-located lanes < i at this level.  The delta joins
            # cvl (the mode's staged in-flight plane), so it widens
            # exploration in "wu" mode and also corrupts Q in "loss" mode,
            # exactly like the jnp lane scan.  One launch per level still.
            iota_l1 = _iota(l, 1, 0)
            iota_1a = _iota(1, a, 1)
            activef = active.astype(jnp.float32)[:, None]  # [L, 1]

            def assign(i, carry):
                delta, sel_acc = carry
                rowsel = iota_l1 == i                      # [L, 1]
                rs = rowsel.astype(jnp.float32)
                row = lambda x: (x * rs).sum(axis=0, keepdims=True)
                cvl_eff = row(cvl) + row(delta)            # [1, A]
                cn_i, cw_i = row(cn), row(cw)
                n_eff = cn_i + cvl_eff
                pnc = jnp.maximum(row(pn[:, None]), 1.0)   # [1, 1]
                if cfg.wu:
                    q = cw_i / jnp.maximum(cn_i, 1.0)
                else:
                    q = (cw_i - cfg.vl_weight * cvl_eff) \
                        / jnp.maximum(n_eff, 1.0)
                if cfg.puct:
                    explore = row(pr) * jnp.sqrt(pnc) / (1.0 + n_eff)
                else:
                    explore = jnp.sqrt(jnp.log(pnc)
                                       / jnp.maximum(n_eff, 1.0))
                s = q + cfg.cp * explore
                s = jnp.where(n_eff < 0.5, 1e30, s)
                ch_i = (ch * rowsel.astype(jnp.int32)).sum(axis=0,
                                                           keepdims=True)
                act_i = row(activef)[0, 0] > 0.5
                s = jnp.where((ch_i >= 0) & act_i, s, NEG_INF)
                sel_i = jnp.argmax(s, axis=1).astype(jnp.int32)    # [1]
                oh = (iota_1a == sel_i[:, None]).astype(jnp.float32)
                node_i = (node[:, None] * rowsel.astype(jnp.int32)) \
                    .sum(axis=0, keepdims=True)            # [1, 1]
                share = (node[:, None] == node_i) & act_i  # [L, 1]
                delta = delta + share.astype(jnp.float32) * oh
                sel_acc = jnp.where(rowsel, sel_i[:, None], sel_acc)
                return delta, sel_acc

            _, sel_acc = jax.lax.fori_loop(
                0, l, assign,
                (jnp.zeros((l, a), jnp.float32), jnp.zeros((l, 1),
                                                           jnp.int32)))
            sel_a = sel_acc[:, 0]
        else:
            n_eff = cn + cvl
            pnc = jnp.maximum(pn, 1.0)
            if cfg.wu:
                q = cw / jnp.maximum(cn, 1.0)
            else:
                q = (cw - cfg.vl_weight * cvl) / jnp.maximum(n_eff, 1.0)
            if cfg.puct:
                explore = pr * jnp.sqrt(pnc)[:, None] / (1.0 + n_eff)
            else:
                explore = jnp.sqrt(jnp.log(pnc)[:, None]
                                   / jnp.maximum(n_eff, 1.0))
            s = q + cfg.cp * explore
            s = jnp.where(n_eff < 0.5, 1e30, s)
            s = jnp.where((ch >= 0) & active[:, None], s, NEG_INF)
            sel_a = jnp.argmax(s, axis=-1).astype(jnp.int32)
        nxt = jnp.where(iota_a == sel_a[:, None], ch, 0).sum(axis=-1) \
            .astype(jnp.int32)
        col = jnp.where(active, depth + 1, p)
        path = jnp.where(iota_p == col[:, None], nxt[:, None], path)
        adds = (_onehot(nxt, n)
                * active.astype(jnp.float32)[:, None]).sum(axis=0)
        vloss_ref[...] = vloss_ref[...] + adds[:, None].astype(jnp.int32)
        node = jnp.where(active, nxt, node)
        depth = depth + own
        active = active & lane_active(node, depth)
        return node, depth, path, active

    leaf, depth, path, _ = jax.lax.fori_loop(
        0, cfg.max_depth, body, (node0, depth0, path0, active0))
    shared = ((leaf[:, None] == leaf[None, :])
              & (_iota(l, l, 0) > _iota(l, l, 1))).any(axis=1)
    dup_w = shared & valid                                 # within this wave
    dup_c = (_gather_vec(vloss_pre.astype(jnp.float32), leaf) > 0.5) & valid
    path = jnp.where(valid[:, None], path, UNEXPANDED)
    return leaf, depth, path, dup_w, dup_c, valid


def _store_sel(s_leaf, s_depth, s_path, s_dup, leaf, depth, path, dup_w,
               dup_c):
    s_leaf[...] = leaf[:, None]
    s_depth[...] = depth[:, None]
    s_path[...] = path
    # [L, 2]: col 0 = within-wave shared leaf, col 1 = cross-wave in-flight
    s_dup[...] = jnp.concatenate(
        [dup_w[:, None], dup_c[:, None]], axis=1).astype(jnp.int32)


def _store_es(e_can, e_slot, e_new, can, slot, new_s):
    e_can[...] = can[:, None].astype(jnp.int32)
    e_slot[...] = slot[:, None]
    e_new[...] = new_s[:, None]


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------
def _se_kernel(vloss_in, children_in, visits, value, prior, terminal,
               free_list, scal, vloss_o, children_o,
               s_leaf, s_depth, s_path, s_dup, e_can, e_slot, e_new, *,
               cfg: WaveCfg):
    del vloss_in, children_in                  # aliased into the outputs
    visits_v = visits[...][:, 0].astype(jnp.float32)
    value_v = value[...][:, 0]
    prior_v = prior[...]
    terminal_v = terminal[...][:, 0].astype(jnp.float32)
    children_v = children_o[...].astype(jnp.float32)   # pre-expand snapshot
    wave_valid = scal[0, 2]
    leaf, depth, path, dup_w, dup_c, valid = _select_phase(
        cfg, vloss_o, visits_v, value_v, prior_v, children_v, terminal_v,
        wave_valid)
    _store_sel(s_leaf, s_depth, s_path, s_dup, leaf, depth, path, dup_w,
               dup_c)
    term_leaf = _gather_vec(terminal_v, leaf) > 0.5
    can, slot, new_s = _expand_phase(
        cfg, children_o, vloss_o, term_leaf, free_list,
        scal[0, 0], scal[0, 1], leaf, valid)
    _store_es(e_can, e_slot, e_new, can, slot, new_s)


def _bes_kernel(visits_in, value_in, vloss_in, prior_in, children_in,
                terminal, free_list, scal, se_leaf, se_valid,
                pb_path, pb_value, pb_priors, pb_node, pb_isnew, pb_valid,
                visits_o, value_o, vloss_o, prior_o, children_o,
                s_leaf, s_depth, s_path, s_dup, e_can, e_slot, e_new, *,
                cfg: WaveCfg):
    del visits_in, value_in, vloss_in, prior_in, children_in   # aliased
    _backup_phase(cfg, visits_o, value_o, vloss_o, prior_o,
                  pb_path[...], pb_value[...], pb_isnew[...], pb_node[...],
                  pb_priors[...], pb_valid[...])
    terminal_v = terminal[...][:, 0].astype(jnp.float32)
    leafs = se_leaf[...][:, 0]
    e_valid = se_valid[...][:, 0] > 0
    term_leaf = _gather_vec(terminal_v, leafs) > 0.5
    can, slot, new_s = _expand_phase(
        cfg, children_o, vloss_o, term_leaf, free_list,
        scal[0, 0], scal[0, 1], leafs, e_valid)
    _store_es(e_can, e_slot, e_new, can, slot, new_s)
    # Select reads children AFTER the structural expand (same tick order as
    # the unfused pipeline); new rows are never descended into (not fully
    # expanded), so their unwritten state/terminal are never consulted.
    visits_v = visits_o[...][:, 0].astype(jnp.float32)
    value_v = value_o[...][:, 0]
    prior_v = prior_o[...]
    children_v = children_o[...].astype(jnp.float32)
    leaf, depth, path, dup_w, dup_c, _ = _select_phase(
        cfg, vloss_o, visits_v, value_v, prior_v, children_v, terminal_v,
        scal[0, 2])
    _store_sel(s_leaf, s_depth, s_path, s_dup, leaf, depth, path, dup_w,
               dup_c)


def _b_kernel(visits_in, value_in, vloss_in, prior_in,
              pb_path, pb_value, pb_priors, pb_node, pb_isnew, pb_valid,
              visits_o, value_o, vloss_o, prior_o, *, cfg: WaveCfg):
    del visits_in, value_in, vloss_in, prior_in               # aliased
    _backup_phase(cfg, visits_o, value_o, vloss_o, prior_o,
                  pb_path[...], pb_value[...], pb_isnew[...], pb_node[...],
                  pb_priors[...], pb_valid[...])


# ---------------------------------------------------------------------------
# launch wrappers (2-D plane packing; ops.py owns arena <-> plane plumbing)
# ---------------------------------------------------------------------------
def _call(kernel, ins, out_shapes, aliases, interpret):
    return pl.pallas_call(
        kernel,
        out_shape=out_shapes,
        input_output_aliases=aliases,
        compiler_params=tpu_compiler_params(dimension_semantics=()),
        interpret=interpret,
    )(*ins)


def _sel_out_shapes(cfg: WaveCfg):
    l, p = cfg.lanes, cfg.path_len
    return [jax.ShapeDtypeStruct((l, 1), jnp.int32),      # leaf
            jax.ShapeDtypeStruct((l, 1), jnp.int32),      # depth
            jax.ShapeDtypeStruct((l, p), jnp.int32),      # path
            jax.ShapeDtypeStruct((l, 2), jnp.int32)]      # dup within|cross


def _es_out_shapes(cfg: WaveCfg):
    l = cfg.lanes
    return [jax.ShapeDtypeStruct((l, 1), jnp.int32)] * 3  # can, slot, new


def se_call(cfg: WaveCfg, vloss, children, visits, value, prior, terminal,
            free_list, scal, *, interpret=False):
    """Select→Expand launch; returns (vloss, children, sel..., es...)."""
    outs = ([jax.ShapeDtypeStruct(vloss.shape, vloss.dtype),
             jax.ShapeDtypeStruct(children.shape, children.dtype)]
            + _sel_out_shapes(cfg) + _es_out_shapes(cfg))
    return _call(functools.partial(_se_kernel, cfg=cfg),
                 [vloss, children, visits, value, prior, terminal,
                  free_list, scal],
                 outs, {0: 0, 1: 1}, interpret)


def bes_call(cfg: WaveCfg, visits, value, vloss, prior, children, terminal,
             free_list, scal, se_leaf, se_valid, pb, *, interpret=False):
    """Backup→Expand→Select launch (one pipeline tick's tree mutations)."""
    outs = ([jax.ShapeDtypeStruct(x.shape, x.dtype)
             for x in (visits, value, vloss, prior, children)]
            + _sel_out_shapes(cfg) + _es_out_shapes(cfg))
    return _call(functools.partial(_bes_kernel, cfg=cfg),
                 [visits, value, vloss, prior, children, terminal, free_list,
                  scal, se_leaf, se_valid] + list(pb),
                 outs, {i: i for i in range(5)}, interpret)


def b_call(cfg: WaveCfg, visits, value, vloss, prior, pb, *,
           interpret=False):
    """Backup-only launch; returns the four updated planes."""
    outs = [jax.ShapeDtypeStruct(x.shape, x.dtype)
            for x in (visits, value, vloss, prior)]
    return _call(functools.partial(_b_kernel, cfg=cfg),
                 [visits, value, vloss, prior] + list(pb),
                 outs, {i: i for i in range(4)}, interpret)
