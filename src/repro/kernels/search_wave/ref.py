"""Reference (pure-jnp) fused search wave — the megakernel's parity oracle.

The fused wave replaces the per-lane ``lax.scan`` Expand stage with one
vectorized structural pass (``expand_wave_struct``) and keeps Select as the
depth-major lockstep descent.  Everything here is constructed to be
BIT-FOR-BIT equal to scanning ``stages.expand_one`` over the wave:

* slot choice — lane l takes the (k+1)-th UNEXPANDED slot of its leaf's
  *pre-wave* children row, where k counts earlier lanes of the wave that
  expanded the same leaf.  That is exactly the first UNEXPANDED slot of the
  row *as the sequential scan would see it*.
* row allocation — lane l's row is the (r+1)-th pop of the arena's
  allocation order (free-list LIFO first, then the ``next_free`` bump),
  where r counts earlier lanes that allocated.  Capacity runs out for the
  trailing lanes exactly as it would sequentially.

The only remaining sequential piece is an O(lanes) bookkeeping scan over
two small carries ([lanes] i32 + scalar) — the tree planes and the domain
``step`` (the expensive parts) are touched once, vectorized.

``finish_expand`` is the out-of-launch half shared with the Pallas path:
child states come from the *domain* (model calls can't run inside a
kernel), so the kernel emits the structural result (``es``) and this glue
vmaps ``domain.step`` over the wave and scatters state/terminal planes.
Ordering safety: the fused pipeline tick runs Select before
``finish_expand``, which is sound because Select never reads a same-tick
node's state or terminal — a just-expanded node is never fully expanded,
so the descent stops at its parent, and only its visits/vloss/children
(written structurally, in-launch) are consulted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.arena import UNEXPANDED, TreeArena


def expand_wave_struct(tree: TreeArena, sp, sel):
    """Structural Expand for a whole wave: allocate rows + link children.

    Returns ``(tree, es)`` where ``es`` carries per-lane ``leaf``, chosen
    ``slot`` (action), allocated ``new`` row (max_nodes sentinel when the
    lane couldn't expand), ``can``, the updated ``path``/``node``, and
    ``valid``.  State/terminal of the new rows are NOT written here — see
    ``finish_expand``.
    """
    from repro.core import stages as S
    leafs, depth, valid = sel["leaf"], sel["depth"], sel["valid"]
    n = tree.max_nodes
    lanes = leafs.shape[0]
    base_row = tree.children[leafs]                       # [L, A] pre-wave
    free_m = base_row == UNEXPANDED
    free_cnt = free_m.sum(axis=-1)
    csum = jnp.cumsum(free_m.astype(jnp.int32), axis=-1)
    term = tree.terminal[leafs]
    nf0, ft0 = tree.next_free, tree.free_top
    cap0 = ft0 + (n - nf0)
    same = leafs[:, None] == leafs[None, :]               # same[l, k]

    def body(carry, l):
        taken, r = carry       # taken[m]: wave slots already used at m's leaf
        can = valid[l] & ~term[l] & (free_cnt[l] > taken[l]) & (r < cap0)
        # (taken[l]+1)-th UNEXPANDED slot == first free slot the sequential
        # scan would see after the earlier same-leaf lanes wrote theirs
        slot = jnp.argmax(free_m[l] & (csum[l] == taken[l] + 1)) \
            .astype(jnp.int32)
        new = jnp.where(
            r < ft0,
            tree.free_list[jnp.clip(ft0 - 1 - r, 0, n - 1)],
            nf0 + (r - ft0)).astype(jnp.int32)
        taken = taken + (same[l] & can).astype(jnp.int32)
        r = r + can.astype(jnp.int32)
        return (taken, r), (can, slot, new)

    (_, r_total), (can, slot, new) = jax.lax.scan(
        body, (jnp.zeros((lanes,), jnp.int32), jnp.asarray(0, jnp.int32)),
        jnp.arange(lanes))

    new_s = jnp.where(can, new, n).astype(jnp.int32)       # OOB -> dropped
    pops = jnp.minimum(r_total, ft0)
    rows = jnp.arange(lanes)
    path = sel["path"].at[rows, depth + 1].set(
        jnp.where(can, new, UNEXPANDED))
    infl = S.infl_plane(tree, sp).at[new_s].add(1, mode="drop")
    tree = S.with_infl(tree, sp, infl).replace(
        children=tree.children.at[
            jnp.where(can, leafs, n), slot].set(new, mode="drop"),
        parent=tree.parent.at[new_s].set(leafs, mode="drop"),
        action=tree.action.at[new_s].set(slot, mode="drop"),
        next_free=nf0 + (r_total - pops),
        free_top=ft0 - pops)
    es = {"leaf": leafs, "slot": slot, "new": new_s, "can": can,
          "path": path, "node": jnp.where(can, new_s, leafs),
          "valid": valid}
    return tree, es


def finish_expand(tree: TreeArena, domain, es):
    """Domain half of Expand (outside any kernel): vmap ``domain.step`` over
    the wave, scatter the new rows' state/terminal, and assemble the
    Expand->Playout buffer.  Shared by the ref and Pallas fused paths."""
    parent_state = jax.tree_util.tree_map(
        lambda x: x[es["leaf"]], tree.state)
    child_state = jax.vmap(domain.step)(parent_state, es["slot"])
    term = jax.vmap(domain.is_terminal)(child_state)
    can, new = es["can"], es["new"]
    tree = tree.replace(
        terminal=tree.terminal.at[new].set(term, mode="drop"),
        state=jax.tree_util.tree_map(
            lambda buf, s: buf.at[new].set(s, mode="drop"),
            tree.state, child_state))
    state = jax.tree_util.tree_map(
        lambda s_par, s_ch: jnp.where(
            jnp.reshape(can, can.shape + (1,) * (jnp.ndim(s_ch) - 1)),
            s_ch, s_par),
        parent_state, child_state)
    return tree, {"path": es["path"], "node": es["node"], "is_new": can,
                  "state": state, "valid": es["valid"]}


def tree_round(tree: TreeArena, domain, sp, lanes: int, valid, rng):
    """Fused tree-parallel round (ref): lockstep Select -> vectorized
    structural Expand -> domain finish -> Playout -> Backup."""
    from repro.core import stages as S
    tree, sel = S.select_wave_fused(tree, sp, lanes, valid)
    tree, es = expand_wave_struct(tree, sp, sel)
    tree, exp = finish_expand(tree, domain, es)
    po = S.playout_wave(domain, sp, exp, rng)
    tree = S.backup_wave(tree, po, sp)
    return tree, sel


def pipeline_tick(tree: TreeArena, domain, sp, lanes: int, wave_valid,
                  buf_se, buf_ep, buf_pb, rng):
    """Fused pipeline tick (ref): B(wave t-3) -> P(wave t-2) -> E(wave t-1,
    structural + finish) -> S(wave t) — the same stage order as the
    unfused tick, with Expand's per-lane scan replaced by the vectorized
    structural pass."""
    from repro.core import stages as S
    tree = S.backup_wave(tree, buf_pb, sp)
    new_pb = S.playout_wave(domain, sp, buf_ep, rng)
    tree, es = expand_wave_struct(tree, sp, buf_se)
    tree, new_ep = finish_expand(tree, domain, es)
    tree, new_se = S.select_wave_fused(tree, sp, lanes, wave_valid)
    return tree, new_se, new_ep, new_pb
