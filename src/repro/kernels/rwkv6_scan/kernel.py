"""Pallas TPU chunked WKV6 kernel (RWKV-6 recurrence).

Grid = (B*H, T/CHUNK); the chunk dimension is sequential with the [N, N]
recurrent state held in VMEM scratch across chunks.  Within a chunk the
recurrence is the matmul-form linear-attention trick (cumulative-decay
rescaling) so the MXU does the work; cumulative sums are computed as a
lower-triangular matmul (MXU-friendly, no serial scan).

TPU adaptation of the CUDA wkv6 kernel (arXiv:2404.05892): instead of one
thread per channel with registers, one (head, chunk) tile per grid step with
VMEM-resident state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                 s_scr, *, chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)          # [C, N]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # [1, N]
    S = s_scr[...]                            # [N, N] (key x value)

    logw = jnp.log(jnp.maximum(w, 1e-38))
    tril_inc = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    cum = jax.lax.dot(tril_inc, logw, preferred_element_type=jnp.float32)
    w_incl = jnp.exp(cum)                     # prod_{s<=t}
    w_excl = jnp.exp(cum - logw)              # prod_{s<t}
    w_tot = jnp.exp(cum[-1:])                 # [1, N]

    r_dec = r * w_excl
    y_state = jax.lax.dot(r_dec, S, preferred_element_type=jnp.float32)
    k_sc = k / jnp.maximum(w_incl, 1e-38)
    att = jax.lax.dot_general(r_dec, k_sc, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [C, C]
    att = att * jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)
    y_intra = jax.lax.dot(att, v, preferred_element_type=jnp.float32)
    bonus = jnp.sum(r * (u * k), axis=1, keepdims=True)            # [C, 1]
    y = y_state + y_intra + bonus * v
    y_ref[0] = y.astype(y_ref.dtype)

    k_dec = k * (w_tot / jnp.maximum(w_incl, 1e-38))
    s_new = S * jnp.transpose(w_tot) + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_scr[...] = s_new

    @pl.when(ci == nc - 1)
    def _fin():
        sT_ref[0] = s_new


def wkv6_bh(r, k, v, w, u, state, *, chunk: int = 64, interpret: bool = False):
    """r,k,v,w [BH, T, N]; u [H, N]; state [BH, N, N] -> (y, final_state)."""
    bh, t, n = r.shape
    h = u.shape[0]
    nc = t // chunk
    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    tile = lambda b, ci: (b, ci, 0)
    y, sT = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, n), tile),
            pl.BlockSpec((1, chunk, n), tile),
            pl.BlockSpec((1, chunk, n), tile),
            pl.BlockSpec((1, chunk, n), tile),
            pl.BlockSpec((1, n), lambda b, ci: (b % h, 0)),
            pl.BlockSpec((1, n, n), lambda b, ci: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, n), tile),
            pl.BlockSpec((1, n, n), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, n), r.dtype),
            jax.ShapeDtypeStruct((bh, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=(pltpu.PARALLEL, pltpu.ARBITRARY)),
        interpret=interpret,
    )(r, k, v, w, u, state)
    return y, sT


def wkv6_pallas(r, k, v, w, u, state, *, chunk: int = 64,
                interpret: bool = False):
    """Public layout: r,k,v,w [B,T,H,N]; u [H,N]; state [B,H,N,N]."""
    b, t, h, n = r.shape
    pad = (-t) % chunk
    tr = lambda x: jnp.pad(x.transpose(0, 2, 1, 3).reshape(b * h, t, n),
                           ((0, 0), (0, pad), (0, 0)))
    rb, kb, vb = tr(r), tr(k), tr(v)
    # pad decay with 1.0 (log 0) so padded steps leave the state unchanged
    wb = jnp.pad(w.transpose(0, 2, 1, 3).reshape(b * h, t, n),
                 ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    sb = state.reshape(b * h, n, n)
    y, sT = wkv6_bh(rb, kb, vb, wb, u, sb, chunk=min(chunk, t + pad),
                    interpret=interpret)
    y = y[:, :t].reshape(b, h, t, n).transpose(0, 2, 1, 3)
    return y, sT.reshape(b, h, n, n)
