"""Pure-jnp oracle for the WKV6 recurrence (RWKV-6 "Finch").

Per head with key/value width N and data-dependent per-channel decay w:

    y_t[i]   = sum_j r_t[j] * (S[j, i] + u[j] * k_t[j] * v_t[i])
    S[j, i] <- w_t[j] * S[j, i] + k_t[j] * v_t[i]

Shapes: r, k, v, w  [B, T, H, N];  u [H, N];  state [B, H, N, N] (key x value).
``w`` is the *decay factor* already in (0, 1) (the model computes
``exp(-exp(w_raw))``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, state):
    """Sequential time scan. Returns (y [B,T,H,N], final_state)."""
    f32 = jnp.float32
    r_, k_, v_, w_ = (x.astype(f32).transpose(1, 0, 2, 3) for x in (r, k, v, w))
    u_ = u.astype(f32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                                  # [B,H,N]
        kv = k_t[..., :, None] * v_t[..., None, :]                # [B,H,N,N]
        y = jnp.einsum("bhj,bhji->bhi", r_t, S + u_[None, :, :, None] * kv)
        S = S * w_t[..., :, None] + kv
        return S, y

    state, ys = jax.lax.scan(step, state.astype(f32), (r_, k_, v_, w_))
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), state


def wkv6_chunked_ref(r, k, v, w, u, state, chunk: int = 16):
    """Chunked formulation (mirrors the Pallas kernel's math).

    Within a chunk of length C, with cumulative decay
    W_t = prod_{s<=t} w_s (exclusive of s=t for the incoming-state term):

      y_t = r_t . (Wcum_t * S_in)            (state contribution)
          + sum_{s<t} r_t . (W_{s+1..t-1}... (intra-chunk, causal)
          + u-bonus diagonal term

    Implemented by rescaling keys/queries with cumulative decays, the standard
    linear-attention chunk trick (Mamba2/GLA/RWKV6 papers).
    """
    b, t, h, n = r.shape
    assert t % chunk == 0, (t, chunk)
    f32 = jnp.float32
    nc = t // chunk
    rs = r.astype(f32).reshape(b, nc, chunk, h, n)
    ks = k.astype(f32).reshape(b, nc, chunk, h, n)
    vs = v.astype(f32).reshape(b, nc, chunk, h, n)
    ws = w.astype(f32).reshape(b, nc, chunk, h, n)
    u_ = u.astype(f32)

    def chunk_step(S, inp):
        rc, kc, vc, wc = inp                                      # [B,C,H,N]
        logw = jnp.log(jnp.maximum(wc, 1e-38))
        cum = jnp.cumsum(logw, axis=1)                            # inclusive
        w_incl = jnp.exp(cum)                                     # prod_{s<=t}
        w_excl = jnp.exp(cum - logw)                              # prod_{s<t}
        w_tot = jnp.exp(cum[:, -1])                               # [B,H,N]

        # state contribution: r_t * prod_{s<t} w_s . S
        r_dec = rc * w_excl
        y_state = jnp.einsum("bchj,bhji->bchi", r_dec, S)
        # intra-chunk causal (strictly lower): A[ts] = r_t . (k_s * W(s+1..t-1? ))
        # k_s contributes to t>s with decay prod_{s<u<=t-1}... using scaled forms:
        # r~_t = r_t * w_excl_t ; k~_s = k_s / w_incl_s  gives decay prod_{s+1..t-1}
        k_sc = kc / jnp.maximum(w_incl, 1e-38)
        att = jnp.einsum("bchj,bshj->bhcs", r_dec, k_sc)          # [B,H,C,C]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        y_intra = jnp.einsum("bhcs,bshi->bchi", att, vc)
        # current-token bonus
        bonus = jnp.einsum("bchj,bchj->bch", rc, u_[None, None] * kc)
        y_bonus = bonus[..., None] * vc
        y = y_state + y_intra + y_bonus
        # state update: S' = w_tot * S + sum_s (prod_{u>s} w_u) k_s v_s^T
        k_dec = kc * (w_tot[:, None] / jnp.maximum(w_incl, 1e-38))
        S = S * w_tot[..., None] + jnp.einsum("bshj,bshi->bhji", k_dec, vc)
        return S, y

    state, ys = jax.lax.scan(
        chunk_step, state.astype(f32),
        tuple(x.transpose(1, 0, 2, 3, 4) for x in (rs, ks, vs, ws)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, h, n)
    return y.astype(r.dtype), state
