"""jit'd dispatch wrapper for the WKV6 recurrence.

``impl``:
  * ``auto``      — chunked for sequences, sequential for single steps;
  * ``sequential``— O(T) scan (exact oracle; bwd saves per-step residuals);
  * ``chunked``   — matmul-form chunks with per-chunk remat (training path;
                    cumulative-decay exponents clamped at -30 in log space,
                    error only where the decay product < 1e-13);
  * ``pallas``    — TPU kernel (interpret=True on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan import ref

LOG_CLAMP = -30.0


def wkv6_chunked(r, k, v, w, u, state, *, chunk: int = 32, remat: bool = True):
    """Chunked WKV6 with clamped log-decay and optional per-chunk remat."""
    b, t, h, n = r.shape
    pad = (-t) % chunk
    if pad:
        padw = lambda x, cv: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)),
                                     constant_values=cv)
        r, k, v = padw(r, 0), padw(k, 0), padw(v, 0)
        w = padw(w, 1.0)
    tt = t + pad
    nc = tt // chunk
    f32 = jnp.float32
    rs = r.reshape(b, nc, chunk, h, n)
    ks = k.reshape(b, nc, chunk, h, n)
    vs = v.reshape(b, nc, chunk, h, n)
    ws = w.reshape(b, nc, chunk, h, n)
    u_ = u.astype(f32)

    def chunk_step(S, inp):
        rc, kc, vc, wc = (t.astype(f32) for t in inp)         # [B,C,H,N]
        logw = jnp.log(jnp.maximum(wc, 1e-38))
        cum = jnp.cumsum(logw, axis=1)
        cum_c = jnp.maximum(cum, LOG_CLAMP)                   # clamped divisor
        w_excl = jnp.exp(cum - logw)
        w_tot = jnp.exp(cum[:, -1])
        r_dec = rc * w_excl
        y_state = jnp.einsum("bchj,bhji->bchi", r_dec, S)
        k_sc = kc * jnp.exp(-cum_c)
        att = jnp.einsum("bchj,bshj->bhcs", r_dec, k_sc)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        y_intra = jnp.einsum("bhcs,bshi->bchi", att, vc)
        bonus = jnp.einsum("bchj,bchj->bch", rc, u_[None, None] * kc)
        y = y_state + y_intra + bonus[..., None] * vc
        k_dec = kc * jnp.exp(jnp.maximum(cum[:, -1][:, None] - cum, LOG_CLAMP))
        S = S * w_tot[..., None] + jnp.einsum("bshj,bshi->bhji", k_dec, vc)
        return S, y

    if remat:
        chunk_step = jax.checkpoint(
            chunk_step, policy=jax.checkpoint_policies.nothing_saveable)
    state, ys = jax.lax.scan(
        chunk_step, state.astype(f32),
        tuple(x.transpose(1, 0, 2, 3, 4) for x in (rs, ks, vs, ws)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, tt, h, n)[:, :t]
    return y.astype(r.dtype), state


def wkv6(r, k, v, w, u, state, *, use_pallas: bool = False,
         interpret: bool = False, impl: str = "auto", chunk: int = 32):
    """(y, new_state). Pallas chunked kernel on TPU, jnp elsewhere."""
    if use_pallas or impl == "pallas":
        from repro.kernels.rwkv6_scan import kernel
        return kernel.wkv6_pallas(r, k, v, w, u, state, interpret=interpret)
    if impl == "chunked" or (impl == "auto" and r.shape[1] > 1):
        return wkv6_chunked(r, k, v, w, u, state, chunk=chunk)
    return ref.wkv6_ref(r, k, v, w, u, state)
