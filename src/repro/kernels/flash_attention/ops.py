"""jit'd wrapper: [B,S,H,D] public layout, padding to MXU-aligned blocks."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref as R


def _pad_to(x, axis, mult):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(q, k, v, *, causal: bool = True, q_offset=0,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = False, use_ref: bool = False):
    """q [B,Sq,H,D]; k, v [B,Sk,Hkv,D] -> [B,Sq,H,D]."""
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    scale = 1.0 / math.sqrt(d)
    qb = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kb = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    vb = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    if use_ref:
        ob = R.attention_bhsd_ref(qb, kb, vb, causal=causal, scale=scale)
        return ob.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
    blk_q_eff = min(blk_q, max(8, 1 << (sq - 1).bit_length()))
    blk_k_eff = min(blk_k, max(8, 1 << (sk - 1).bit_length()))
    qp = _pad_to(qb, 1, blk_q_eff)
    kp = _pad_to(kb, 1, blk_k_eff)
    vp = _pad_to(vb, 1, blk_k_eff)
    ob = K.flash_attention_bhsd(qp, kp, vp, causal=causal, scale=scale,
                                blk_q=blk_q_eff, blk_k=blk_k_eff,
                                seq_k_valid=sk, interpret=interpret)
    ob = ob[:, :sq]
    return ob.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
