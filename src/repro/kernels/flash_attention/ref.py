"""Pure-jnp oracle for flash attention (same layout as the kernel)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_bhsd_ref(q, k, v, *, causal: bool, scale: float):
    """q [BH, Sq, D]; k, v [BHkv, Sk, D] (GQA by head-group repetition)."""
    bh, sq, d = q.shape
    bhkv, sk, _ = k.shape
    group = bh // bhkv
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
