"""Pallas TPU flash attention (fwd): blocked online softmax, causal, GQA.

Grid = (B*H, Sq/BLK_Q, Sk/BLK_K); the last (kv) dimension is ``ARBITRARY``
(sequential) so the per-(head, q-block) running max / denom / accumulator
scratch persists across kv steps — the canonical TPU flash pattern.  GQA is
handled in the kv index_map (no materialized head repetition).  MXU dims are
kept 128-aligned by the ops-layer padding.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, blk_q: int, blk_k: int,
               seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * blk_q
    k_start = ki * blk_k
    # skip kv blocks entirely above the causal diagonal
    run = (not causal) or (q_start + blk_q - 1 >= k_start)
    run_pred = jnp.asarray(True) if not causal else (q_start + blk_q - 1 >= k_start)

    @pl.when(run_pred)
    def _body():
        q = q_ref[0].astype(jnp.float32)                      # [BLK_Q, D]
        k = k_ref[0].astype(jnp.float32)                      # [BLK_K, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        # mask kv padding (seq_k may be < padded length)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < seq_k, s, NEG_INF)

        m_prev = m_scr[...]                                   # [BLK_Q, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool, scale: float,
                         blk_q: int = 128, blk_k: int = 128,
                         seq_k_valid: int = None, interpret: bool = False):
    """q [BH, Sq, D]; k, v [BHkv, Sk, D]; returns [BH, Sq, D].

    Sq/Sk must be multiples of the block sizes (ops layer pads).
    """
    bh, sq, d = q.shape
    bhkv, sk, _ = k.shape
    group = bh // bhkv
    nq, nk = sq // blk_q, sk // blk_k
    seq_k = seq_k_valid if seq_k_valid is not None else sk

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, blk_q=blk_q, blk_k=blk_k,
        seq_k=seq_k)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, qi, ki: (b // group, ki, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, qi, ki: (b // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL, pltpu.ARBITRARY)),
        interpret=interpret,
    )(q, k, v)
