"""Pallas TPU fused UCT score + masked argmax over children tiles.

The Select stage's hot op (paper eq. 1): for a batch of R tree nodes with A
children each, compute UCT scores with virtual loss and return the best child
index per node — fused in VMEM, no [R, A] score array round-trip through HBM.
Action width is lane-padded to 128 by the ops layer.

R is the wave axis: the lockstep Select stage (DESIGN.md §11) issues ONE
launch per tree level with R = lanes, so a whole wave's children score in a
single [R, 128·k] VMEM tile instead of R single-row launches.  Rows may
duplicate a parent (co-located lanes) and rows whose ``valid`` mask is all
zero (finished lanes) argmax over -inf to index 0, which callers discard.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params

NEG_INF = -1e30


def _uct_kernel(n_ref, w_ref, vl_ref, uo_ref, pn_ref, valid_ref, out_ref, *,
                cp: float, vl_weight: float, wu: bool):
    n = n_ref[...].astype(jnp.float32)           # [BLK_R, A]
    w = w_ref[...]
    vl = vl_ref[...].astype(jnp.float32)
    uo = uo_ref[...].astype(jnp.float32)         # [BLK_R, A] unobs counts O
    pn = pn_ref[...].astype(jnp.float32)         # [BLK_R, 1]
    valid = valid_ref[...]                       # [BLK_R, A] int32 mask
    if wu:
        # WU-UCT: O widens exploration only; Q from completed stats.
        n_eff = n + uo
        q = w / jnp.maximum(n, 1.0)
    else:
        n_eff = n + vl
        q = (w - vl_weight * vl) / jnp.maximum(n_eff, 1.0)
    explore = jnp.sqrt(jnp.log(jnp.maximum(pn, 1.0)) / jnp.maximum(n_eff, 1.0))
    s = q + cp * explore
    s = jnp.where(n_eff < 0.5, 1e30, s)          # idle unvisited -> must explore
    s = jnp.where(valid > 0, s, NEG_INF)
    # first-max argmax: sentinel ties resolve to the lowest index (ref parity)
    out_ref[...] = jnp.argmax(s, axis=1, keepdims=True).astype(jnp.int32)


def uct_argmax_tiles(child_n, child_w, child_vl, child_o, parent_n, valid, *,
                     cp: float, vl_weight: float, wu: bool = False,
                     blk_r: int = 256, interpret: bool = False):
    """All [R, A] (A lane-padded); parent_n [R, 1] -> best index [R, 1] i32."""
    r, a = child_n.shape
    nr = pl.cdiv(r, blk_r)
    kernel = functools.partial(_uct_kernel, cp=cp, vl_weight=vl_weight, wu=wu)
    row = lambda i: (i, 0)
    return pl.pallas_call(
        kernel,
        grid=(nr,),
        in_specs=[pl.BlockSpec((blk_r, a), row) for _ in range(4)]
        + [pl.BlockSpec((blk_r, 1), row), pl.BlockSpec((blk_r, a), row)],
        out_specs=pl.BlockSpec((blk_r, 1), row),
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=(pltpu.PARALLEL,)),
        interpret=interpret,
    )(child_n, child_w, child_vl, child_o, parent_n, valid)
