"""Pallas TPU fused UCT score + masked argmax over children tiles.

The Select stage's hot op (paper eq. 1): for a batch of R tree nodes with A
children each, compute UCT scores with virtual loss and return the best child
index per node — fused in VMEM, no [R, A] score array round-trip through HBM.
Action width is lane-padded to 128 by the ops layer.

R is the wave axis: the lockstep Select stage (DESIGN.md §11) issues ONE
launch per tree level with R = lanes, so a whole wave's children score in a
single [R, 128·k] VMEM tile instead of R single-row launches.  Rows may
duplicate a parent (co-located lanes) and rows whose ``valid`` mask is all
zero (finished lanes) argmax over -inf to index 0, which callers discard.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params

NEG_INF = -1e30


def _uct_kernel(n_ref, w_ref, vl_ref, uo_ref, pn_ref, valid_ref, out_ref, *,
                cp: float, vl_weight: float, wu: bool):
    n = n_ref[...].astype(jnp.float32)           # [BLK_R, A]
    w = w_ref[...]
    vl = vl_ref[...].astype(jnp.float32)
    uo = uo_ref[...].astype(jnp.float32)         # [BLK_R, A] unobs counts O
    pn = pn_ref[...].astype(jnp.float32)         # [BLK_R, 1]
    valid = valid_ref[...]                       # [BLK_R, A] int32 mask
    if wu:
        # WU-UCT: O widens exploration only; Q from completed stats.
        n_eff = n + uo
        q = w / jnp.maximum(n, 1.0)
    else:
        n_eff = n + vl
        q = (w - vl_weight * vl) / jnp.maximum(n_eff, 1.0)
    explore = jnp.sqrt(jnp.log(jnp.maximum(pn, 1.0)) / jnp.maximum(n_eff, 1.0))
    s = q + cp * explore
    s = jnp.where(n_eff < 0.5, 1e30, s)          # idle unvisited -> must explore
    s = jnp.where(valid > 0, s, NEG_INF)
    # first-max argmax: sentinel ties resolve to the lowest index (ref parity)
    out_ref[...] = jnp.argmax(s, axis=1, keepdims=True).astype(jnp.int32)


def uct_argmax_tiles(child_n, child_w, child_vl, child_o, parent_n, valid, *,
                     cp: float, vl_weight: float, wu: bool = False,
                     blk_r: int = 256, interpret: bool = False):
    """All [R, A] (A lane-padded); parent_n [R, 1] -> best index [R, 1] i32."""
    r, a = child_n.shape
    nr = pl.cdiv(r, blk_r)
    kernel = functools.partial(_uct_kernel, cp=cp, vl_weight=vl_weight, wu=wu)
    row = lambda i: (i, 0)
    return pl.pallas_call(
        kernel,
        grid=(nr,),
        in_specs=[pl.BlockSpec((blk_r, a), row) for _ in range(4)]
        + [pl.BlockSpec((blk_r, 1), row), pl.BlockSpec((blk_r, a), row)],
        out_specs=pl.BlockSpec((blk_r, 1), row),
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=(pltpu.PARALLEL,)),
        interpret=interpret,
    )(child_n, child_w, child_vl, child_o, parent_n, valid)


def _uct_running_kernel(n_ref, w_ref, vl_ref, uo_ref, pn_ref, valid_ref,
                        pid_ref, out_ref, *, cp: float, vl_weight: float,
                        wu: bool):
    """Running-assignment wave argmax (DESIGN.md §16): a sequential row walk
    inside ONE launch.  Row i scores with a running in-flight accumulator
    already incremented by the picks of rows 0..i-1 that share row i's
    parent id — dup-parent rows share one accumulator; rows with a distinct
    parent are untouched.  The accumulator joins ``vl`` in loss mode (Q and
    effective count) and ``uo`` in wu mode (exploration only).  A row whose
    ``valid`` mask is all zero contributes nothing and returns index 0.
    Rows are extracted with masked reductions (no dynamic row slicing), so
    the walk is O(R^2·A) VPU work — R is the wave's lane count, small.
    """
    n = n_ref[...].astype(jnp.float32)               # [R, A]
    w = w_ref[...]
    vl = vl_ref[...].astype(jnp.float32)
    uo = uo_ref[...].astype(jnp.float32)
    pn = pn_ref[...].astype(jnp.float32)             # [R, 1]
    valid = valid_ref[...]                           # [R, A] int32 mask
    pid = pid_ref[...]                               # [R, 1] int32 parent ids
    r, a = n.shape
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (r, 1), 0)
    iota_a1 = jax.lax.broadcasted_iota(jnp.int32, (1, a), 1)
    activef = (valid.sum(axis=1, keepdims=True) > 0).astype(jnp.float32)

    def body(i, carry):
        contrib, out = carry
        rowsel = iota_r == i                         # [R, 1]
        rs = rowsel.astype(jnp.float32)
        row = lambda x: (x * rs).sum(axis=0, keepdims=True)
        d_i = row(contrib)                           # [1, A] running counts
        n_i, w_i = row(n), row(w)
        va_i = row(valid.astype(jnp.float32))
        pn_i = row(pn)                               # [1, 1]
        if wu:
            n_eff = n_i + (row(uo) + d_i)
            q = w_i / jnp.maximum(n_i, 1.0)
        else:
            vle = row(vl) + d_i
            n_eff = n_i + vle
            q = (w_i - vl_weight * vle) / jnp.maximum(n_eff, 1.0)
        explore = jnp.sqrt(jnp.log(jnp.maximum(pn_i, 1.0))
                           / jnp.maximum(n_eff, 1.0))
        s = q + cp * explore
        s = jnp.where(n_eff < 0.5, 1e30, s)
        s = jnp.where(va_i > 0, s, NEG_INF)
        sel = jnp.argmax(s, axis=1).astype(jnp.int32)    # [1], first-max
        onehot = (iota_a1 == sel[:, None]).astype(jnp.float32)
        pid_i = (pid * rowsel.astype(jnp.int32)).sum(axis=0, keepdims=True)
        act_i = row(activef)[0, 0] > 0.5
        share = ((pid == pid_i) & act_i).astype(jnp.float32)   # [R, 1]
        contrib = contrib + share * onehot
        out = jnp.where(rowsel, sel[:, None], out)
        return contrib, out

    _, out = jax.lax.fori_loop(
        0, r, body,
        (jnp.zeros((r, a), jnp.float32), jnp.zeros((r, 1), jnp.int32)))
    out_ref[...] = out


def uct_argmax_running_call(child_n, child_w, child_vl, child_o, parent_n,
                            valid, parent_id, *, cp: float, vl_weight: float,
                            wu: bool = False, interpret: bool = False):
    """All [R, A] (A lane-padded), parent_n/parent_id [R, 1] -> [R, 1] i32.
    Whole-array blocks, single launch: the running walk needs every row of
    the wave in one tile (no ``blk_r`` grid — R is a lane count)."""
    r, _ = child_n.shape
    kernel = functools.partial(_uct_running_kernel, cp=cp,
                               vl_weight=vl_weight, wu=wu)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.int32),
        compiler_params=tpu_compiler_params(dimension_semantics=()),
        interpret=interpret,
    )(child_n, child_w, child_vl, child_o, parent_n, valid, parent_id)
