"""Oracle for the fused UCT argmax — delegates to repro.core.uct scoring.

Shares the kernel's wave contract: rows are independent (lanes), duplicated
parents are fine, and an all-invalid row returns index 0 (argmax over -inf).
Sentinel ties (several idle unvisited children all at 1e30) resolve to the
lowest index — first-max argmax, same as the kernel.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import uct


def uct_argmax_ref(child_n, child_w, child_vl, parent_n, valid, *,
                   cp: float, vl_weight: float, child_o=None,
                   vl_mode: str = "loss"):
    s = uct.uct_scores(child_n, child_w, child_vl, parent_n, cp,
                       vl_weight=vl_weight, child_o=child_o, vl_mode=vl_mode)
    s = jnp.where(valid, s, uct.NEG_INF)
    return jnp.argmax(s, axis=-1).astype(jnp.int32)


def uct_argmax_running_ref(child_n, child_w, child_vl, parent_n, parent_id,
                           valid, *, cp: float, vl_weight: float,
                           child_o=None, vl_mode: str = "loss"):
    """Oracle for the running-assignment kernel — the jnp lane scan."""
    return uct.uct_argmax_running(
        child_n, child_w, child_vl, parent_n, parent_id, cp,
        vl_weight=vl_weight, valid=valid, use_pallas=False,
        child_o=child_o, vl_mode=vl_mode)
