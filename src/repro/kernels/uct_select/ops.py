"""jit'd wrapper for fused UCT argmax. Accepts [..., A] stats, pads A->128.

Row batching is the wave contract (DESIGN.md §11): the lockstep Select stage
calls this once per tree level with ``r = lanes`` rows — rows may repeat the
same parent's stats (co-located lanes), carry ragged ``valid`` masks, or be
entirely invalid (finished lanes).  An all-invalid row deterministically
returns index 0 (every score is -inf; callers discard masked lanes), and
``blk_r`` is rounded up to the 8-row sublane multiple so wave-sized row
counts (8, 12, 16, ...) tile cleanly on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.uct_select import kernel as K
from repro.kernels.uct_select import ref as R


def uct_argmax(child_n, child_w, child_vl, parent_n, *, vl_weight=1.0,
               valid=None, interpret: bool = False, use_ref: bool = False,
               cp=None, child_o=None, vl_mode: str = "loss"):
    if cp is None:
        raise TypeError("cp is required")
    batch_shape = child_n.shape[:-1]
    a = child_n.shape[-1]
    if valid is None:
        valid = jnp.ones(child_n.shape, bool)
    if child_o is None:
        child_o = jnp.zeros(child_n.shape, jnp.int32)
    if use_ref:
        return R.uct_argmax_ref(child_n, child_w, child_vl, parent_n, valid,
                                cp=float(cp), vl_weight=vl_weight,
                                child_o=child_o, vl_mode=vl_mode)
    r = int(np.prod(batch_shape)) if batch_shape else 1
    pad_a = (-a) % 128
    n2 = child_n.reshape(r, a).astype(jnp.float32)
    w2 = child_w.reshape(r, a).astype(jnp.float32)
    v2 = child_vl.reshape(r, a).astype(jnp.float32)
    o2 = child_o.reshape(r, a).astype(jnp.float32)
    pn = jnp.reshape(parent_n, (r, 1)).astype(jnp.float32) if jnp.ndim(parent_n) \
        else jnp.full((r, 1), parent_n, jnp.float32)
    va = valid.reshape(r, a).astype(jnp.int32)
    if pad_a:
        z = lambda x, fill: jnp.pad(x, ((0, 0), (0, pad_a)), constant_values=fill)
        n2, w2, v2, o2, va = z(n2, 1), z(w2, 0), z(v2, 0), z(o2, 0), z(va, 0)
    blk_r = min(256, max(8, r + (-r) % 8))     # sublane-aligned row tile
    pad_r = (-r) % blk_r
    if pad_r:
        zr = lambda x: jnp.pad(x, ((0, pad_r), (0, 0)), constant_values=1)
        n2, w2, v2, o2, pn = zr(n2), zr(w2), zr(v2), zr(o2), zr(pn)
        va = jnp.pad(va, ((0, pad_r), (0, 0)),
                     constant_values=0).at[r:, 0].set(1)
    out = K.uct_argmax_tiles(n2, w2, v2, o2, pn, va, cp=float(cp),
                             vl_weight=float(vl_weight),
                             wu=(vl_mode == "wu"), blk_r=blk_r,
                             interpret=interpret)
    out = out[:r, 0]
    return out.reshape(batch_shape) if batch_shape else out[0]


def uct_argmax_running(child_n, child_w, child_vl, parent_n, parent_id, *,
                       vl_weight=1.0, valid=None, interpret: bool = False,
                       use_ref: bool = False, cp=None, child_o=None,
                       vl_mode: str = "loss"):
    """Running-assignment variant (DESIGN.md §16): strictly ``[lanes, A]``.

    Rows are a wave's lanes scored in order — lane k's in-flight plane is
    pre-incremented by the picks of same-parent lanes < k, so the whole wave
    stays one launch but the walk inside it is sequential.  Row padding is
    inert (contributions flow forward-only and padded rows trail the real
    ones with parent id -1, matching no real row), but the tile must hold
    every lane at once, so ``blk_r`` covers all rows — no 256 cap.
    """
    if cp is None:
        raise TypeError("cp is required")
    if child_n.ndim != 2:
        raise ValueError("uct_argmax_running expects [lanes, A] stats, got "
                         f"shape {child_n.shape}")
    r, a = child_n.shape
    if valid is None:
        valid = jnp.ones((r, a), bool)
    if child_o is None:
        child_o = jnp.zeros((r, a), jnp.int32)
    if use_ref:
        return R.uct_argmax_running_ref(
            child_n, child_w, child_vl, parent_n, parent_id, valid,
            cp=float(cp), vl_weight=vl_weight, child_o=child_o,
            vl_mode=vl_mode)
    pad_a = (-a) % 128
    n2 = child_n.astype(jnp.float32)
    w2 = child_w.astype(jnp.float32)
    v2 = child_vl.astype(jnp.float32)
    o2 = child_o.astype(jnp.float32)
    pn = jnp.reshape(parent_n, (r, 1)).astype(jnp.float32) if jnp.ndim(parent_n) \
        else jnp.full((r, 1), parent_n, jnp.float32)
    pid = jnp.reshape(parent_id, (r, 1)).astype(jnp.int32)
    va = valid.astype(jnp.int32)
    if pad_a:
        z = lambda x, fill: jnp.pad(x, ((0, 0), (0, pad_a)), constant_values=fill)
        n2, w2, v2, o2, va = z(n2, 1), z(w2, 0), z(v2, 0), z(o2, 0), z(va, 0)
    blk_r = max(8, r + (-r) % 8)               # one sublane-aligned tile
    pad_r = blk_r - r
    if pad_r:
        zr = lambda x: jnp.pad(x, ((0, pad_r), (0, 0)), constant_values=1)
        n2, w2, v2, o2, pn = zr(n2), zr(w2), zr(v2), zr(o2), zr(pn)
        va = jnp.pad(va, ((0, pad_r), (0, 0)),
                     constant_values=0).at[r:, 0].set(1)
        pid = jnp.pad(pid, ((0, pad_r), (0, 0)), constant_values=-1)
    out = K.uct_argmax_running_call(n2, w2, v2, o2, pn, va, pid,
                                    cp=float(cp), vl_weight=float(vl_weight),
                                    wu=(vl_mode == "wu"), interpret=interpret)
    return out[:r, 0]
