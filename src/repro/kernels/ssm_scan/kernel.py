"""Pallas TPU chunked Mamba-2 SSD kernel.

Grid = (B*H, T/CHUNK); chunk dimension sequential, [P, N] state in VMEM
scratch.  Intra-chunk work is the SSD matmul form (arXiv:2405.21060 §6) —
cumulative log-decays via triangular matmul, decay-weighted C·Bᵀ attention —
so the MXU executes the recurrence.  B/C projections are shared across heads
(single group) and indexed per-batch in the BlockSpec, not materialized per
head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params


def _ssd_kernel(x_ref, dt_ref, la_ref, b_ref, c_ref, s0_ref, y_ref, sT_ref,
                s_scr, *, chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)          # [C, P]
    dt = dt_ref[0].astype(jnp.float32)        # [C, 1]
    la = la_ref[0].astype(jnp.float32)        # [C, 1]  log decay
    Bc = b_ref[0].astype(jnp.float32)         # [C, N]
    Cc = c_ref[0].astype(jnp.float32)         # [C, N]
    S = s_scr[...]                            # [P, N]

    tril_inc = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    cum = jax.lax.dot(tril_inc, la, preferred_element_type=jnp.float32)  # [C,1]
    seg = jnp.exp(cum)                        # prod_{s<=t} a_s
    # state contribution: y_t = seg_t * C_t . S^T
    y_state = seg * jax.lax.dot_general(
        Cc, S, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)  # [C,P]
    # intra-chunk: w[t,s] = (C_t.B_s) * exp(cum_t - cum_s), s <= t
    att = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)            # [C,C]
    dec = jnp.exp(cum - jnp.transpose(cum))
    w = att * dec * tril_inc
    xdt = x * dt
    y = y_state + jax.lax.dot(w, xdt, preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    tot = jnp.exp(cum[-1:])                   # [1, 1]
    k_dec = jnp.exp(cum[-1:] - cum)           # [C, 1]
    s_new = S * tot + jax.lax.dot_general(
        xdt * k_dec, Bc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # [P, N]
    s_scr[...] = s_new

    @pl.when(ci == nc - 1)
    def _fin():
        sT_ref[0] = s_new


def ssd_bh(x, dt, la, Bm, Cm, state, *, n_heads: int, chunk: int = 64,
           interpret: bool = False):
    """x [BH,T,P]; dt, la [BH,T,1]; Bm, Cm [B,T,N]; state [BH,P,N]."""
    bh, t, p = x.shape
    n = Bm.shape[-1]
    nc = t // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    tile = lambda b, ci: (b, ci, 0)
    shared = lambda b, ci: (b // n_heads, ci, 0)
    y, sT = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), tile),
            pl.BlockSpec((1, chunk, 1), tile),
            pl.BlockSpec((1, chunk, 1), tile),
            pl.BlockSpec((1, chunk, n), shared),
            pl.BlockSpec((1, chunk, n), shared),
            pl.BlockSpec((1, p, n), lambda b, ci: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), tile),
            pl.BlockSpec((1, p, n), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, p), x.dtype),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=(pltpu.PARALLEL, pltpu.ARBITRARY)),
        interpret=interpret,
    )(x, dt, la, Bm, Cm, state)
    return y, sT


def ssd_pallas(x, dt, A, Bm, Cm, D, state, *, chunk: int = 64,
               interpret: bool = False):
    """Public layout: x [B,T,H,P]; dt [B,T,H]; A,D [H]; Bm,Cm [B,T,N];
    state [B,H,P,N] -> (y [B,T,H,P], final_state)."""
    b, t, h, p = x.shape
    n = Bm.shape[-1]
    pad = (-t) % chunk
    xb = jnp.pad(x.transpose(0, 2, 1, 3).reshape(b * h, t, p),
                 ((0, 0), (0, pad), (0, 0)))
    dtb = jnp.pad(dt.transpose(0, 2, 1).reshape(b * h, t, 1),
                  ((0, 0), (0, pad), (0, 0)))
    la = dt * A[None, None, :]
    lab = jnp.pad(la.transpose(0, 2, 1).reshape(b * h, t, 1),
                  ((0, 0), (0, pad), (0, 0)))      # pad log-decay 0 => decay 1
    Bp = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
    Cp = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    sb = state.reshape(b * h, p, n)
    y, sT = ssd_bh(xb, dtb, lab, Bp, Cp, sb, n_heads=h,
                   chunk=min(chunk, t + pad), interpret=interpret)
    y = y[:, :t].reshape(b, h, t, p).transpose(0, 2, 1, 3)
    y = y + D.astype(y.dtype)[None, None, :, None] * x.astype(y.dtype)
    return y, sT.reshape(b, h, p, n)
