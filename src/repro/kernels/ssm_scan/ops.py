"""jit'd dispatch wrapper for the Mamba-2 SSD scan.

``impl``: auto (chunked for sequences) | sequential | chunked | pallas.
The chunked path is numerically safe without clamping (decays <= 1, all
exponents non-positive) and runs with per-chunk remat for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import ref


def ssd_chunked(x, dt, A, Bm, Cm, D, state, *, chunk: int = 64,
                remat: bool = True):
    b, t, h, p = x.shape
    n = Bm.shape[-1]
    pad = (-t) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    tt = t + pad
    nc = tt // chunk
    f32 = jnp.float32
    xs = x.reshape(b, nc, chunk, h, p)
    dts = dt.astype(f32).reshape(b, nc, chunk, h)
    Bs = Bm.reshape(b, nc, chunk, n)
    Cs = Cm.reshape(b, nc, chunk, n)
    A_ = A.astype(f32)

    def chunk_step(S, inp):
        xc, dtc, Bc, Cc = (t.astype(f32) for t in inp)
        la = dtc * A_[None, None]
        cum = jnp.cumsum(la, axis=1)
        seg = jnp.exp(cum)
        y_state = jnp.einsum("bcn,bhpn,bch->bchp", Cc, S, seg)
        att = jnp.einsum("bcn,bsn->bcs", Cc, Bc)
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        wgt = att[..., None] * jnp.where(mask[None, :, :, None], dec, 0.0)
        xdt = xc * dtc[..., None]
        y = y_state + jnp.einsum("bcsh,bshp->bchp", wgt, xdt)
        tot = jnp.exp(cum[:, -1])
        k_dec = jnp.exp(cum[:, -1][:, None] - cum)
        S = S * tot[:, :, None, None] + jnp.einsum(
            "bch,bchp,bcn->bhpn", k_dec * dtc, xc, Bc)
        return S, y

    if remat:
        chunk_step = jax.checkpoint(
            chunk_step, policy=jax.checkpoint_policies.nothing_saveable)
    state, ys = jax.lax.scan(
        chunk_step, state.astype(f32),
        (xs.transpose(1, 0, 2, 3, 4), dts.transpose(1, 0, 2, 3),
         Bs.transpose(1, 0, 2, 3), Cs.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, tt, h, p)[:, :t]
    y = y + D.astype(f32)[None, None, :, None] * x.astype(f32)[:, :t]
    return y.astype(x.dtype), state


def ssd(x, dt, A, Bm, Cm, D, state, *, use_pallas: bool = False,
        interpret: bool = False, impl: str = "auto", chunk: int = 64):
    """(y, new_state). Pallas chunked kernel on TPU, jnp elsewhere."""
    if use_pallas or impl == "pallas":
        from repro.kernels.ssm_scan import kernel
        return kernel.ssd_pallas(x, dt, A, Bm, Cm, D, state, interpret=interpret)
    if impl == "chunked" or (impl == "auto" and x.shape[1] > 1):
        return ssd_chunked(x, dt, A, Bm, Cm, D, state, chunk=chunk)
    return ref.ssd_ref(x, dt, A, Bm, Cm, D, state)
