"""Pure-jnp oracle for the Mamba-2 SSD recurrence (arXiv:2405.21060).

Per head with head-dim P and state-dim N:

    a_t      = exp(dt_t * A)                     (A < 0 scalar per head)
    S_t      = a_t * S_{t-1} + dt_t * x_t B_t^T  (S in R^{P x N})
    y_t      = S_t C_t + D * x_t

Shapes: x [B,T,H,P]; dt [B,T,H]; A,D [H]; Bm,Cm [B,T,N] (single group);
state [B,H,P,N].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bm, Cm, D, state):
    """Sequential time scan. Returns (y [B,T,H,P], final_state)."""
    f32 = jnp.float32
    xT = x.astype(f32).transpose(1, 0, 2, 3)          # [T,B,H,P]
    dtT = dt.astype(f32).transpose(1, 0, 2)           # [T,B,H]
    BT = Bm.astype(f32).transpose(1, 0, 2)            # [T,B,N]
    CT = Cm.astype(f32).transpose(1, 0, 2)
    A_ = A.astype(f32)
    D_ = D.astype(f32)

    def step(S, inp):
        x_t, dt_t, B_t, C_t = inp
        a_t = jnp.exp(dt_t * A_)                      # [B,H]
        upd = (dt_t[..., None] * x_t)[..., :, None] * B_t[:, None, None, :]
        S = S * a_t[..., None, None] + upd            # [B,H,P,N]
        y = jnp.einsum("bhpn,bn->bhp", S, C_t) + D_[None, :, None] * x_t
        return S, y

    state, ys = jax.lax.scan(step, state.astype(f32), (xT, dtT, BT, CT))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), state


def ssd_chunked_ref(x, dt, A, Bm, Cm, D, state, chunk: int = 16):
    """Chunked (matmul-form) SSD — mirrors the Pallas kernel's math."""
    b, t, h, p = x.shape
    n = Bm.shape[-1]
    assert t % chunk == 0
    f32 = jnp.float32
    nc = t // chunk
    xs = x.astype(f32).reshape(b, nc, chunk, h, p)
    dts = dt.astype(f32).reshape(b, nc, chunk, h)
    Bs = Bm.astype(f32).reshape(b, nc, chunk, n)
    Cs = Cm.astype(f32).reshape(b, nc, chunk, n)
    A_ = A.astype(f32)

    def chunk_step(S, inp):
        xc, dtc, Bc, Cc = inp                         # [B,C,H,P],[B,C,H],[B,C,N]
        la = dtc * A_[None, None]                     # log a_t  [B,C,H]
        cum = jnp.cumsum(la, axis=1)                  # inclusive  [B,C,H]
        seg = jnp.exp(cum)                            # prod_{s<=t} a_s
        # y state contribution: C_t . (prod_{s<=t} a_s) S
        y_state = jnp.einsum("bcn,bhpn,bch->bchp", Cc, S, seg)
        # intra-chunk: pair (t,s), s<=t: decay prod_{s<u<=t} a_u = seg_t/seg_s
        att = jnp.einsum("bcn,bsn->bcs", Cc, Bc)      # [B,C,C]
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # [B,C,S,H]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        w = att[..., None] * jnp.where(mask[None, :, :, None], dec, 0.0)
        xdt = xc * dtc[..., None]                     # [B,C,H,P]
        y_intra = jnp.einsum("bcsh,bshp->bchp", w, xdt)
        y = y_state + y_intra
        # state update
        tot = jnp.exp(cum[:, -1])                     # [B,H]
        k_dec = jnp.exp(cum[:, -1][:, None] - cum)    # prod_{u>s} a_u  [B,C,H]
        S = S * tot[:, :, None, None] + jnp.einsum(
            "bch,bchp,bcn->bhpn", k_dec * dtc, xc, Bc)
        return S, y

    state, ys = jax.lax.scan(
        chunk_step, state.astype(f32),
        tuple(a.transpose(1, 0, 2, 3, 4) if a.ndim == 5 else a.transpose(1, 0, 2, 3)
              for a in (xs, dts, Bs, Cs)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, h, p)
    y = y + D.astype(f32)[None, None, :, None] * x.astype(f32)
    return y.astype(x.dtype), state
