"""Pallas TPU flash-decode: one query row vs. a long KV cache.

Grid = (B*H, Skv/BLK_K), kv dimension sequential with (m, l, acc) VMEM
scratch.  Per-sequence valid lengths arrive via scalar prefetch (SMEM) so
fully-invalid kv blocks are skipped — the split-K flash-decode pattern of the
decode_32k / long_500k serving cells.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.parallel.compat import tpu_compiler_params

NEG_INF = -1e30


def _dec_kernel(valid_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                *, scale: float, blk_k: int, n_heads: int):
    bh = pl.program_id(0)
    ki = pl.program_id(1)
    nk = pl.num_programs(1)
    b = bh // n_heads
    valid = valid_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ki * blk_k

    @pl.when(k_start < valid)
    def _body():
        q = q_ref[0].astype(jnp.float32)                      # [1, D]
        k = k_ref[0].astype(jnp.float32)                      # [BLK_K, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_bhd(q, k, v, valid_len, *, scale: float,
                         blk_k: int = 512, interpret: bool = False):
    """q [BH, 1, D]; k, v [BHkv, Sk, D]; valid_len [B] i32 -> [BH, 1, D]."""
    bh, _, d = q.shape
    bhkv, sk, _ = k.shape
    group = bh // bhkv
    nb = valid_len.shape[0]
    n_heads = bh // nb
    nk = pl.cdiv(sk, blk_k)

    kernel = functools.partial(_dec_kernel, scale=scale, blk_k=blk_k,
                               n_heads=n_heads)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, nk),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b, ki, v_: (b, 0, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, ki, v_: (b // group, ki, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, ki, v_: (b // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b, ki, v_: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=(pltpu.PARALLEL, pltpu.ARBITRARY)),
        interpret=interpret,
    )(valid_len, q, k, v)
