"""jit'd wrapper for flash-decode: [B,1,H,D] public layout."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import kernel as K
from repro.kernels.decode_attention import ref as R


def decode_attention(q, k, v, valid_len, *, blk_k: int = 512,
                     interpret: bool = False, use_ref: bool = False):
    """q [B,1,H,D]; k, v [B,Sk,Hkv,D]; valid_len [B] -> [B,1,H,D]."""
    b, one, h, d = q.shape
    _, sk, hkv, _ = k.shape
    scale = 1.0 / math.sqrt(d)
    if use_ref:
        return R.decode_attention_ref(q, k, v, valid_len, scale=scale)
    blk = min(blk_k, max(128, 1 << (sk - 1).bit_length()))
    pad = (-sk) % blk
    qb = q.transpose(0, 2, 1, 3).reshape(b * h, 1, d)
    kb = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    vb = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    if pad:
        kb = jnp.pad(kb, ((0, 0), (0, pad), (0, 0)))
        vb = jnp.pad(vb, ((0, 0), (0, pad), (0, 0)))
    ob = K.decode_attention_bhd(qb, kb, vb, valid_len.astype(jnp.int32),
                                scale=scale, blk_k=blk, interpret=interpret)
    return ob.reshape(b, h, 1, d).transpose(0, 2, 1, 3)
