"""Pure-jnp oracle for flash-decode (single-query attention w/ valid mask)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, valid_len, *, scale: float):
    """q [B,1,H,D]; k, v [B,Sk,Hkv,D]; valid_len [B] -> [B,1,H,D]."""
    b, _, h, d = q.shape
    _, sk, hkv, _ = k.shape
    group = h // hkv
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    keep = jnp.arange(sk)[None, :] < valid_len[:, None]
    s = jnp.where(keep[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)
