"""LR schedules. WSD (Warmup-Stable-Decay) per MiniCPM (arXiv:2404.06395)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup, warm, cos).astype(jnp.float32)
    return f


def wsd(lr: float, warmup: int, stable: int, decay: int, final_frac: float = 0.01):
    """Warmup -> Stable (flat) -> Decay (exponential-ish linear-in-log)."""
    def f(step):
        s = step.astype(jnp.float32)
        warm = lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = lr * jnp.exp(jnp.log(jnp.maximum(final_frac, 1e-6)) * t)
        out = jnp.where(s < warmup, warm, jnp.where(s < warmup + stable, lr, dec))
        return out.astype(jnp.float32)
    return f
