"""Optimizers (pure JAX, optax-style (init, update) pairs).

Optimizer state lives in the same sharding as the parameters' logical axes
(ZeRO: m/v inherit the param PartitionSpec), so launch/dryrun shards it with
the rules table — no replicated optimizer memory.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, state, params, lr) -> (updates, new_state)


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": _tmap(zeros, params), "v": _tmap(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        b1t = 1 - b1 ** step.astype(jnp.float32)
        b2t = 1 - b2 ** step.astype(jnp.float32)
        m = _tmap(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(state_dtype),
                  state["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(state_dtype)),
                  state["v"], grads)
        def upd(m_, v_, p):
            mhat = m_ / b1t
            vhat = v_ / b2t
            u = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(state_dtype)
            return (-lr * u).astype(p.dtype)
        updates = _tmap(upd, m, v, params)
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def lion(b1: float = 0.9, b2: float = 0.99, weight_decay: float = 0.1,
         state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        return {"m": _tmap(lambda p: jnp.zeros(p.shape, state_dtype), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        def upd(m_, g, p):
            g32 = g.astype(state_dtype)
            c = b1 * m_ + (1 - b1) * g32
            return (-lr * (jnp.sign(c) + weight_decay * p.astype(state_dtype))).astype(p.dtype)
        updates = _tmap(upd, state["m"], grads, params)
        m = _tmap(lambda m_, g: b2 * m_ + (1 - b2) * g.astype(state_dtype),
                  state["m"], grads)
        return updates, {"m": m, "step": state["step"] + 1}

    return Optimizer(init, update)


def sgd(momentum: float = 0.9, state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        return {"m": _tmap(lambda p: jnp.zeros(p.shape, state_dtype), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        m = _tmap(lambda m_, g: momentum * m_ + g.astype(state_dtype),
                  state["m"], grads)
        updates = _tmap(lambda m_, p: (-lr * m_).astype(p.dtype), m, params)
        return updates, {"m": m, "step": state["step"] + 1}

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return _tmap(lambda g: (g * scale).astype(g.dtype), grads), gn


def apply_updates(params, updates):
    return _tmap(lambda p, u: (p + u).astype(p.dtype), params, updates)
