from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adamw, lion, sgd, clip_by_global_norm, apply_updates,
)
from repro.optim.schedules import constant, cosine, wsd  # noqa: F401
