"""The four MCTS operation-level tasks (OLT) as pure stage functions.

Paper §V-A: Select / Expand / Playout / Backup, with hard OLD dependencies
S→E→P→B inside one trajectory and soft ILD between trajectories.  Each stage
here is a pure function (tree, inputs) -> (tree, outputs) — the tree is the
typed ``core.arena.TreeArena`` — so the pipeline scheduler can compose them
over in-flight waves.

Serial stages (E, B) process a wave's lanes sequentially (scan) — matching
the paper's serial pipeline stages.  The Playout stage is fully parallel
(vmap) — the paper's replicated playout stage (Fig. 5).

Kernel/selection knobs (DESIGN.md §11/§14) — one consolidated pair on
``SearchParams``, threaded down from ``SearchConfig``:

* ``kernels`` — "auto" | "pallas" | "ref": which implementation backs the
  accelerated paths ("auto" resolves to "pallas" on TPU, "ref" elsewhere).
  The old boolean ``use_pallas`` is accepted and forwarded under a
  ``DeprecationWarning``.
* ``vl_mode`` — in-flight decorrelation statistics (DESIGN.md §15):
    - "loss" — classic virtual loss: one ``vloss`` plane, added to N and
      subtracted (×``vl_weight``) from W, so Q is pessimistically corrupted
      while playouts are in flight (the historical default);
    - "wu"   — WU-UCT (arXiv 1810.11755): a separate ``unobs`` plane O that
      widens only the exploration term; Q = W/max(N,1) from completed
      statistics only.  The non-active plane stays all-zeros.
* ``wave_select`` — Select-stage iteration order:
    - "scan"     — lane-major: lane i+1 descends after lane i, seeing its
      virtual loss at every level (the original serial Select stage);
    - "lockstep" — depth-major: all lanes descend together, one batched
      ``[lanes, A]`` UCT argmax per tree level;
    - "mega"     — the fused select→expand→backup wave
      (``kernels/search_wave``): the whole lockstep descent plus the
      structural expand (and the pipeline tick's backup) in one launch
      against the arena planes, instead of a launch per tree level.
      Bit-for-bit equal to "lockstep" at ``lanes == 1``.
    - "auto"     — "mega" when the resolved kernels are Pallas, else "scan"
      (preserving the historical CPU default).
* ``level_assign`` — within-level lane assignment for the depth-major paths
  (lockstep/mega; DESIGN.md §16): "independent" scores every lane against an
  identical board (co-located lanes stack), "running" threads a
  running-assignment scan through the batched level pass so lane k sees
  lanes 0..k-1's same-level picks and co-located lanes spread.  No-op for
  "scan" (lane-major already serializes whole descents).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import uct
from repro.core.arena import alloc as arena_alloc
from repro.core.tree import ROOT, UNEXPANDED, Tree, get_state, max_nodes


WAVE_SELECT_MODES = ("auto", "scan", "lockstep", "mega")
KERNEL_MODES = ("auto", "pallas", "ref")
LEVEL_ASSIGN_MODES = ("independent", "running")


@dataclasses.dataclass(frozen=True)
class SearchParams:
    cp: float = 1.414
    vl_weight: float = 1.0
    max_depth: int = 32
    puct: bool = False
    # In-flight decorrelation statistics: "loss" (virtual loss, default —
    # unchanged behaviour) or "wu" (WU-UCT unobserved counts, DESIGN §15).
    vl_mode: str = "loss"
    # Which implementation backs the accelerated paths ("auto" -> "pallas"
    # on TPU, "ref" elsewhere).  One knob for the per-level UCT kernel and
    # the fused search-wave megakernel alike.
    kernels: str = "auto"
    # Select-stage iteration order (see module docstring).
    wave_select: str = "auto"
    # Within-level lane assignment for the depth-major paths (DESIGN.md §16):
    # "independent" — co-located lanes score an identical board and may stack
    # on one child until Expand fans them out (the historical behaviour);
    # "running"     — a running-assignment scan inside the batched level
    # pass: lane k scores with the in-flight plane already incremented by
    # lanes 0..k-1's picks at that same level, so one launch per level still
    # serves the whole wave but co-located lanes spread over viable
    # children.  A documented no-op for wave_select="scan" (the lane-major
    # descent already sees earlier lanes' counts at every level).
    level_assign: str = "independent"
    # DEPRECATED: the old boolean kernel switch.  Accepted and forwarded
    # into ``kernels`` ("pallas"/"ref") when ``kernels`` is left at "auto".
    use_pallas: Optional[bool] = None

    def __post_init__(self):
        if self.vl_mode not in uct.VL_MODES:
            raise ValueError(
                f"vl_mode must be one of {uct.VL_MODES}, got {self.vl_mode!r}")
        if self.level_assign not in LEVEL_ASSIGN_MODES:
            raise ValueError(
                f"level_assign must be one of {LEVEL_ASSIGN_MODES}, "
                f"got {self.level_assign!r}")
        if self.use_pallas is not None:
            warnings.warn(
                "SearchParams.use_pallas is deprecated; use "
                "kernels='pallas'|'ref' (forwarding "
                f"use_pallas={self.use_pallas!r})", DeprecationWarning,
                stacklevel=2)
            if self.kernels == "auto":
                object.__setattr__(
                    self, "kernels", "pallas" if self.use_pallas else "ref")

    @property
    def wu(self) -> bool:
        return self.vl_mode == "wu"

    @property
    def running(self) -> bool:
        return self.level_assign == "running"

    @property
    def path_len(self) -> int:
        return self.max_depth + 2          # root .. deepest leaf + expanded child

    @property
    def resolved_kernels(self) -> str:
        if self.kernels not in KERNEL_MODES:
            raise ValueError(
                f"kernels must be one of {KERNEL_MODES}, got {self.kernels!r}")
        if self.kernels == "auto":
            return "pallas" if jax.default_backend() == "tpu" else "ref"
        return self.kernels

    @property
    def pallas_enabled(self) -> bool:
        return self.resolved_kernels == "pallas"

    @property
    def resolved_wave_select(self) -> str:
        if self.wave_select not in WAVE_SELECT_MODES:
            raise ValueError(
                f"wave_select must be one of {WAVE_SELECT_MODES}, "
                f"got {self.wave_select!r}")
        if self.wave_select == "auto":
            return "mega" if self.pallas_enabled else "scan"
        return self.wave_select


def empty_selection(sp: SearchParams, lanes: int):
    return {
        "path": jnp.full((lanes, sp.path_len), UNEXPANDED, jnp.int32),
        "leaf": jnp.zeros((lanes,), jnp.int32),
        "depth": jnp.zeros((lanes,), jnp.int32),
        "valid": jnp.zeros((lanes,), bool),
        "dup": jnp.zeros((lanes,), bool),
        "dup_within": jnp.zeros((lanes,), bool),
        "dup_cross": jnp.zeros((lanes,), bool),
    }


def empty_expansion(sp: SearchParams, lanes: int, domain):
    state = jax.tree_util.tree_map(
        lambda x: jnp.zeros((lanes,) + jnp.shape(x), jnp.asarray(x).dtype),
        domain.root_state())
    return {
        "path": jnp.full((lanes, sp.path_len), UNEXPANDED, jnp.int32),
        "node": jnp.zeros((lanes,), jnp.int32),
        "is_new": jnp.zeros((lanes,), bool),
        "state": state,
        "valid": jnp.zeros((lanes,), bool),
    }


def empty_playout(sp: SearchParams, lanes: int, num_actions: int):
    return {
        "path": jnp.full((lanes, sp.path_len), UNEXPANDED, jnp.int32),
        "node": jnp.zeros((lanes,), jnp.int32),
        "is_new": jnp.zeros((lanes,), bool),
        "value": jnp.zeros((lanes,), jnp.float32),
        "priors": jnp.zeros((lanes, num_actions), jnp.float32),
        "valid": jnp.zeros((lanes,), bool),
    }


def infl_plane(tree: Tree, sp: SearchParams):
    """The mode's in-flight counter plane: ``unobs`` ("wu") / ``vloss``
    ("loss").  Static selection — the other plane stays all-zeros."""
    return tree.unobs if sp.wu else tree.vloss


def with_infl(tree: Tree, sp: SearchParams, plane) -> Tree:
    """Write ``plane`` back to the mode's in-flight field."""
    return tree.replace(unobs=plane) if sp.wu else tree.replace(vloss=plane)


# ---------------------------------------------------------------------------
# SELECT — UCT descent with in-flight decorrelation (serial stage)
# ---------------------------------------------------------------------------
def select_one(tree: Tree, sp: SearchParams, valid):
    """Descend from the root; returns (tree+in-flight, trajectory dict)."""
    def cond(c):
        node, depth, _ = c
        fully = (tree.children[node] >= 0).all()
        return fully & ~tree.terminal[node] & (depth < sp.max_depth)

    infl = infl_plane(tree, sp)

    def body(c):
        node, depth, path = c
        ch = tree.children[node]
        idx = jnp.maximum(ch, 0)
        a = uct.uct_argmax(
            tree.visits[idx], tree.value[idx], infl[idx],
            tree.visits[node] + infl[node], sp.cp,
            vl_weight=sp.vl_weight, prior=tree.prior[node],
            puct=sp.puct, valid=ch >= 0, use_pallas=sp.pallas_enabled,
            child_o=infl[idx], vl_mode=sp.vl_mode)
        nxt = ch[a]
        path = path.at[depth + 1].set(nxt)
        return nxt, depth + 1, path

    path0 = jnp.full((sp.path_len,), UNEXPANDED, jnp.int32).at[0].set(ROOT)
    leaf, depth, path = jax.lax.while_loop(cond, body, (jnp.int32(ROOT), jnp.int32(0), path0))
    dup = (infl[leaf] > 0) & valid
    mask = (path >= 0) & valid
    tree = with_infl(
        tree, sp,
        infl.at[jnp.maximum(path, 0)].add(mask.astype(jnp.int32)))
    sel = {"path": jnp.where(valid, path, UNEXPANDED), "leaf": leaf,
           "depth": depth, "valid": valid, "dup": dup}
    return tree, sel


def select_wave_scan(tree: Tree, sp: SearchParams, lanes: int, valid):
    """Lane-major Select: lane i+1 sees lane i's virtual loss (paper Fig. 5:
    one serial Select stage feeding multiple playout stages)."""
    infl_pre = infl_plane(tree, sp)   # in-flight counts before this wave

    def body(tr, _):
        tr, sel = select_one(tr, sp, valid)
        return tr, sel

    tree, sels = jax.lax.scan(body, tree, None, length=lanes)
    # split the dup event (a leaf that already had in-flight playouts) into
    # its two sources: an earlier unfinished wave (cross) vs a lower-numbered
    # valid lane of THIS wave (within).  Only a same-wave lane's own leaf can
    # carry within-wave in-flight counts — interior path nodes are fully
    # expanded and can never be another lane's leaf — so dup == within|cross.
    leaf, v = sels["leaf"], sels["valid"]
    sels["dup_within"] = (jnp.tril(leaf[:, None] == leaf[None, :], k=-1)
                          & v[None, :]).any(axis=1) & v
    sels["dup_cross"] = (infl_pre[leaf] > 0) & v
    return tree, sels


def select_wave_fused(tree: Tree, sp: SearchParams, lanes: int, valid):
    """Depth-major lockstep Select (DESIGN.md §11): every loop iteration is
    one tree level, scoring all active lanes' children with a single batched
    ``[lanes, A]`` UCT argmax — one ``uct_argmax_tiles`` launch with
    ``r = lanes`` under Pallas kernels, instead of ``lanes`` single-row
    calls per level.

    The in-flight count (``vloss`` in "loss" mode, ``unobs`` in "wu" mode)
    is applied per level: every selected child gets +1 before the next
    level's scores are computed, so deeper levels see the whole wave's
    in-flight counts (tree-parallel decorrelation).  How lanes at the SAME
    level see each other is ``sp.level_assign`` (DESIGN.md §16):
    "independent" scores the whole board at once (co-located lanes pick
    identically until Expand fans them out); "running" assigns lanes in
    order within the level — lane k's board row carries the picks of lanes
    0..k-1 sharing its parent, so co-located lanes spread over viable
    children while one batched call per level still serves the wave.  A
    lane's own count on its current node is excluded from ``parent_n``,
    which makes the descent bit-for-bit identical to ``select_wave_scan``
    at ``lanes == 1`` in either assignment mode (the running delta is
    identically zero for a single lane).
    Finished/invalid lanes mask out via the argmax's ``valid`` lanes.
    """
    valid = jnp.broadcast_to(jnp.asarray(valid, bool), (lanes,))
    nmax = max_nodes(tree)
    rows = jnp.arange(lanes)
    infl_pre = infl_plane(tree, sp)   # in-flight counts before this wave

    def lane_active(node, depth):
        fully = (tree.children[node] >= 0).all(axis=-1)
        return fully & ~tree.terminal[node] & (depth < sp.max_depth)

    # root in-flight count up front: the root is on every valid lane's path
    infl0 = infl_pre.at[ROOT].add(valid.sum().astype(jnp.int32))
    node0 = jnp.full((lanes,), ROOT, jnp.int32)
    depth0 = jnp.zeros((lanes,), jnp.int32)
    path0 = jnp.full((lanes, sp.path_len), UNEXPANDED, jnp.int32) \
        .at[:, 0].set(ROOT)
    active0 = valid & lane_active(node0, depth0)

    def cond(c):
        return c[4].any()

    def body(c):
        infl, node, depth, path, active = c
        ch = tree.children[node]                           # [lanes, A]
        idx = jnp.maximum(ch, 0)
        own = active.astype(jnp.int32)         # own in-flight count
        pn = tree.visits[node] + infl[node] - own
        kw = dict(vl_weight=sp.vl_weight, prior=tree.prior[node],
                  puct=sp.puct, valid=(ch >= 0) & active[:, None],
                  use_pallas=sp.pallas_enabled,
                  child_o=infl[idx], vl_mode=sp.vl_mode)
        if sp.running:    # lane k's row sees lanes 0..k-1's picks (§16)
            a = uct.uct_argmax_running(
                tree.visits[idx], tree.value[idx], infl[idx], pn, node,
                sp.cp, **kw)
        else:
            a = uct.uct_argmax(
                tree.visits[idx], tree.value[idx], infl[idx], pn, sp.cp,
                **kw)
        nxt = ch[rows, a]
        col = jnp.where(active, depth + 1, sp.path_len)    # OOB -> dropped
        path = path.at[rows, col].set(nxt, mode="drop")
        infl = infl.at[jnp.where(active, nxt, nmax)].add(1, mode="drop")
        node = jnp.where(active, nxt, node)
        depth = depth + own
        active = active & lane_active(node, depth)
        return infl, node, depth, path, active

    infl, leaf, depth, path, _ = jax.lax.while_loop(
        cond, body, (infl0, node0, depth0, path0, active0))
    tree = with_infl(tree, sp, infl)
    # same meaning as the scan path's dup: the lane's leaf was already
    # in-flight when it arrived — split into its two sources: an earlier
    # unfinished wave (cross), or a lower-numbered lane of this wave
    # (within — the stacking that level_assign="running" removes when the
    # leaf's parent still has viable siblings)
    dup_within = (jnp.tril(leaf[:, None] == leaf[None, :], k=-1)
                  .any(axis=1)) & valid
    dup_cross = (infl_pre[leaf] > 0) & valid
    sel = {"path": jnp.where(valid[:, None], path, UNEXPANDED),
           "leaf": leaf, "depth": depth, "valid": valid,
           "dup": dup_within | dup_cross,
           "dup_within": dup_within, "dup_cross": dup_cross}
    return tree, sel


def select_wave(tree: Tree, sp: SearchParams, lanes: int, valid):
    """Dispatch on ``sp.resolved_wave_select`` (static at trace time).
    "mega" at this stage-level granularity descends exactly like
    "lockstep" — the fusion with expand/backup happens one level up
    (``mega_round`` / ``mega_tick``)."""
    if sp.resolved_wave_select in ("lockstep", "mega"):
        return select_wave_fused(tree, sp, lanes, valid)
    return select_wave_scan(tree, sp, lanes, valid)


# ---------------------------------------------------------------------------
# EXPAND — allocate one child per trajectory (serial stage)
# ---------------------------------------------------------------------------
def expand_one(tree: Tree, domain, sp: SearchParams, sel):
    leaf, depth, valid = sel["leaf"], sel["depth"], sel["valid"]
    row = tree.children[leaf]
    has_slot = (row == UNEXPANDED).any()
    can_try = valid & has_slot & ~tree.terminal[leaf]
    tree, new, can = arena_alloc(tree, can_try)
    a = jnp.argmax(row == UNEXPANDED).astype(jnp.int32)
    parent_state = get_state(tree, leaf)
    child_state = domain.step(parent_state, a)
    term = domain.is_terminal(child_state)

    nmax = max_nodes(tree)
    state = jax.tree_util.tree_map(
        lambda buf, s: buf.at[new].set(s, mode="drop"),
        tree.state, child_state)
    infl_upd = {("unobs" if sp.wu else "vloss"):
                infl_plane(tree, sp).at[new].add(1, mode="drop")}
    tree = tree.replace(
        children=tree.children.at[
            jnp.where(can, leaf, nmax), a].set(new, mode="drop"),
        parent=tree.parent.at[new].set(leaf, mode="drop"),
        action=tree.action.at[new].set(a, mode="drop"),
        terminal=tree.terminal.at[new].set(term, mode="drop"),
        state=state, **infl_upd)

    node = jnp.where(can, new, leaf)
    path = sel["path"].at[depth + 1].set(jnp.where(can, new, UNEXPANDED))
    state = jax.tree_util.tree_map(
        lambda s_par, s_ch: jnp.where(
            jnp.reshape(can, (1,) * jnp.ndim(s_ch)), s_ch, s_par)
        if jnp.ndim(s_ch) else jnp.where(can, s_ch, s_par),
        parent_state, child_state)
    return tree, {"path": path, "node": node, "is_new": can, "state": state,
                  "valid": valid}


def expand_wave(tree: Tree, domain, sp: SearchParams, sels):
    def body(tr, sel):
        tr, exp = expand_one(tr, domain, sp, sel)
        return tr, exp

    tree, exps = jax.lax.scan(body, tree, sels)
    return tree, exps


# ---------------------------------------------------------------------------
# PLAYOUT — parallel stage (vmap over lanes; paper Fig. 5 replicated stage)
# ---------------------------------------------------------------------------
def playout_wave(domain, sp: SearchParams, exp, rng):
    lanes = exp["node"].shape[0]
    rngs = jax.random.split(rng, lanes)
    values = jax.vmap(domain.playout)(exp["state"], rngs)
    if hasattr(domain, "priors"):
        priors = jax.vmap(domain.priors)(exp["state"])
    else:
        a = domain.num_actions
        priors = jnp.full((lanes, a), 1.0 / a, jnp.float32)
    return {"path": exp["path"], "node": exp["node"], "is_new": exp["is_new"],
            "value": values.astype(jnp.float32), "priors": priors,
            "valid": exp["valid"]}


# ---------------------------------------------------------------------------
# BACKUP — scatter-add along paths (commutative => order-independent)
# ---------------------------------------------------------------------------
def backup_wave(tree: Tree, po, sp: Optional[SearchParams] = None):
    """Scatter-add N/W along paths and drain the mode's in-flight plane.
    ``sp=None`` keeps the historical signature and means "loss" mode."""
    paths = po["path"]                                     # [L, P]
    valid = po["valid"]
    mask = (paths >= 0) & valid[:, None]
    idx = jnp.maximum(paths, 0).reshape(-1)
    m = mask.reshape(-1)
    vals = jnp.broadcast_to(po["value"][:, None], paths.shape).reshape(-1)
    # write priors for freshly created nodes
    widx = jnp.where(po["is_new"] & valid, po["node"], max_nodes(tree))
    wu = sp is not None and sp.wu
    infl = (tree.unobs if wu else tree.vloss).at[idx].add(-m.astype(jnp.int32))
    return tree.replace(
        visits=tree.visits.at[idx].add(m.astype(jnp.int32)),
        value=tree.value.at[idx].add(jnp.where(m, vals, 0.0)),
        prior=tree.prior.at[widx].set(po["priors"], mode="drop"),
        **{("unobs" if wu else "vloss"): infl})


# ---------------------------------------------------------------------------
# MEGA — fused select→expand(→backup) waves (kernels/search_wave, §14)
# ---------------------------------------------------------------------------
def mega_round(tree: Tree, domain, sp: SearchParams, lanes: int, valid, rng):
    """One tree-parallel round as two fused launches: [select→expand] +
    playout + [backup].  Replaces select_wave + expand_wave's
    scan-over-lanes with the fused wave; bit-for-bit equal to the lockstep
    path at ``lanes == 1``.  Returns (tree, sel)."""
    from repro.kernels.search_wave import ops as wave
    return wave.tree_round(tree, domain, sp, lanes, valid, rng)


def mega_tick(tree: Tree, domain, sp: SearchParams, lanes: int, wave_valid,
              buf_se, buf_ep, buf_pb, rng):
    """One pipeline tick as a single fused [backup→expand→select] launch
    plus the out-of-launch playout and expand-finish (domain model calls
    cannot run inside a kernel).  Returns (tree, new_se, new_ep, new_pb)."""
    from repro.kernels.search_wave import ops as wave
    return wave.pipeline_tick(tree, domain, sp, lanes, wave_valid,
                              buf_se, buf_ep, buf_pb, rng)
