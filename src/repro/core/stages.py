"""The four MCTS operation-level tasks (OLT) as pure stage functions.

Paper §V-A: Select / Expand / Playout / Backup, with hard OLD dependencies
S→E→P→B inside one trajectory and soft ILD between trajectories.  Each stage
here is a pure function (tree, inputs) -> (tree, outputs) so the pipeline
scheduler can compose them over in-flight waves.

Serial stages (S, E, B) process a wave's lanes sequentially (scan) — matching
the paper's serial pipeline stages, and letting virtual loss decorrelate lanes
within a wave.  The Playout stage is fully parallel (vmap) — the paper's
replicated playout stage (Fig. 5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import uct
from repro.core.tree import ROOT, UNEXPANDED, Tree, get_state, max_nodes


@dataclasses.dataclass(frozen=True)
class SearchParams:
    cp: float = 1.414
    vl_weight: float = 1.0
    max_depth: int = 32
    puct: bool = False
    use_pallas: bool = False

    @property
    def path_len(self) -> int:
        return self.max_depth + 2          # root .. deepest leaf + expanded child


def empty_selection(sp: SearchParams, lanes: int):
    return {
        "path": jnp.full((lanes, sp.path_len), UNEXPANDED, jnp.int32),
        "leaf": jnp.zeros((lanes,), jnp.int32),
        "depth": jnp.zeros((lanes,), jnp.int32),
        "valid": jnp.zeros((lanes,), bool),
        "dup": jnp.zeros((lanes,), bool),
    }


def empty_expansion(sp: SearchParams, lanes: int, domain):
    state = jax.tree_util.tree_map(
        lambda x: jnp.zeros((lanes,) + jnp.shape(x), jnp.asarray(x).dtype),
        domain.root_state())
    return {
        "path": jnp.full((lanes, sp.path_len), UNEXPANDED, jnp.int32),
        "node": jnp.zeros((lanes,), jnp.int32),
        "is_new": jnp.zeros((lanes,), bool),
        "state": state,
        "valid": jnp.zeros((lanes,), bool),
    }


def empty_playout(sp: SearchParams, lanes: int, num_actions: int):
    return {
        "path": jnp.full((lanes, sp.path_len), UNEXPANDED, jnp.int32),
        "node": jnp.zeros((lanes,), jnp.int32),
        "is_new": jnp.zeros((lanes,), bool),
        "value": jnp.zeros((lanes,), jnp.float32),
        "priors": jnp.zeros((lanes, num_actions), jnp.float32),
        "valid": jnp.zeros((lanes,), bool),
    }


# ---------------------------------------------------------------------------
# SELECT — UCT descent with virtual loss (serial stage)
# ---------------------------------------------------------------------------
def select_one(tree: Tree, sp: SearchParams, valid):
    """Descend from the root; returns (tree+vl, trajectory dict of scalars)."""
    def cond(c):
        node, depth, _ = c
        fully = (tree["children"][node] >= 0).all()
        return fully & ~tree["terminal"][node] & (depth < sp.max_depth)

    def body(c):
        node, depth, path = c
        ch = tree["children"][node]
        idx = jnp.maximum(ch, 0)
        a = uct.uct_argmax(
            tree["visits"][idx], tree["value"][idx], tree["vloss"][idx],
            tree["visits"][node] + tree["vloss"][node], sp.cp,
            vl_weight=sp.vl_weight, prior=tree["prior"][node],
            puct=sp.puct, valid=ch >= 0, use_pallas=sp.use_pallas)
        nxt = ch[a]
        path = path.at[depth + 1].set(nxt)
        return nxt, depth + 1, path

    path0 = jnp.full((sp.path_len,), UNEXPANDED, jnp.int32).at[0].set(ROOT)
    leaf, depth, path = jax.lax.while_loop(cond, body, (jnp.int32(ROOT), jnp.int32(0), path0))
    dup = (tree["vloss"][leaf] > 0) & valid
    mask = (path >= 0) & valid
    tree = dict(tree)
    tree["vloss"] = tree["vloss"].at[jnp.maximum(path, 0)].add(mask.astype(jnp.int32))
    sel = {"path": jnp.where(valid, path, UNEXPANDED), "leaf": leaf,
           "depth": depth, "valid": valid, "dup": dup}
    return tree, sel


def select_wave(tree: Tree, sp: SearchParams, lanes: int, valid):
    """Serial over lanes: lane i+1 sees lane i's virtual loss (paper Fig. 5:
    one serial Select stage feeding multiple playout stages)."""
    def body(tr, _):
        tr, sel = select_one(tr, sp, valid)
        return tr, sel

    tree, sels = jax.lax.scan(body, tree, None, length=lanes)
    return tree, sels


# ---------------------------------------------------------------------------
# EXPAND — allocate one child per trajectory (serial stage)
# ---------------------------------------------------------------------------
def expand_one(tree: Tree, domain, sp: SearchParams, sel):
    leaf, depth, valid = sel["leaf"], sel["depth"], sel["valid"]
    row = tree["children"][leaf]
    has_slot = (row == UNEXPANDED).any()
    not_full = tree["next_free"] < max_nodes(tree)
    can = valid & has_slot & ~tree["terminal"][leaf] & not_full
    a = jnp.argmax(row == UNEXPANDED).astype(jnp.int32)
    new = tree["next_free"]
    parent_state = get_state(tree, leaf)
    child_state = domain.step(parent_state, a)
    term = domain.is_terminal(child_state)

    widx = jnp.where(can, new, max_nodes(tree))            # OOB -> dropped
    tree = dict(tree)
    tree["children"] = tree["children"].at[jnp.where(can, leaf, max_nodes(tree)), a].set(new, mode="drop")
    tree["parent"] = tree["parent"].at[widx].set(leaf, mode="drop")
    tree["action"] = tree["action"].at[widx].set(a, mode="drop")
    tree["terminal"] = tree["terminal"].at[widx].set(term, mode="drop")
    tree["vloss"] = tree["vloss"].at[widx].add(1, mode="drop")
    tree["state"] = jax.tree_util.tree_map(
        lambda buf, s: buf.at[widx].set(s, mode="drop"), tree["state"], child_state)
    tree["next_free"] = tree["next_free"] + can.astype(jnp.int32)

    node = jnp.where(can, new, leaf)
    path = sel["path"].at[depth + 1].set(jnp.where(can, new, UNEXPANDED))
    state = jax.tree_util.tree_map(
        lambda s_par, s_ch: jnp.where(
            jnp.reshape(can, (1,) * jnp.ndim(s_ch)), s_ch, s_par)
        if jnp.ndim(s_ch) else jnp.where(can, s_ch, s_par),
        parent_state, child_state)
    return tree, {"path": path, "node": node, "is_new": can, "state": state,
                  "valid": valid}


def expand_wave(tree: Tree, domain, sp: SearchParams, sels):
    def body(tr, sel):
        tr, exp = expand_one(tr, domain, sp, sel)
        return tr, exp

    tree, exps = jax.lax.scan(body, tree, sels)
    return tree, exps


# ---------------------------------------------------------------------------
# PLAYOUT — parallel stage (vmap over lanes; paper Fig. 5 replicated stage)
# ---------------------------------------------------------------------------
def playout_wave(domain, sp: SearchParams, exp, rng):
    lanes = exp["node"].shape[0]
    rngs = jax.random.split(rng, lanes)
    values = jax.vmap(domain.playout)(exp["state"], rngs)
    if hasattr(domain, "priors"):
        priors = jax.vmap(domain.priors)(exp["state"])
    else:
        a = domain.num_actions
        priors = jnp.full((lanes, a), 1.0 / a, jnp.float32)
    return {"path": exp["path"], "node": exp["node"], "is_new": exp["is_new"],
            "value": values.astype(jnp.float32), "priors": priors,
            "valid": exp["valid"]}


# ---------------------------------------------------------------------------
# BACKUP — scatter-add along paths (commutative => order-independent)
# ---------------------------------------------------------------------------
def backup_wave(tree: Tree, po):
    paths = po["path"]                                     # [L, P]
    valid = po["valid"]
    mask = (paths >= 0) & valid[:, None]
    idx = jnp.maximum(paths, 0).reshape(-1)
    m = mask.reshape(-1)
    vals = jnp.broadcast_to(po["value"][:, None], paths.shape).reshape(-1)
    tree = dict(tree)
    tree["visits"] = tree["visits"].at[idx].add(m.astype(jnp.int32))
    tree["value"] = tree["value"].at[idx].add(jnp.where(m, vals, 0.0))
    tree["vloss"] = tree["vloss"].at[idx].add(-m.astype(jnp.int32))
    # write priors for freshly created nodes
    widx = jnp.where(po["is_new"] & valid, po["node"], max_nodes(tree))
    tree["prior"] = tree["prior"].at[widx].set(po["priors"], mode="drop")
    return tree
