"""Typed structure-of-arrays tree arena with node recycling (DESIGN.md §14).

``TreeArena`` is the single tree representation behind ``core.tree``,
``core.stages``, every search strategy, and serving's cross-token
``tree_reuse`` carry.  It is a frozen dataclass registered as a jax pytree,
so it jits/vmaps/scans exactly like the raw dict it replaces, while giving
the planes a typed, documented layout:

    visits    [N] i32     visit count n_j
    value     [N] f32     reward sum  w_j
    vloss     [N] i32     virtual-loss counters (in-flight trajectories,
                          ``vl_mode="loss"``)
    unobs     [N] i32     WU-UCT unobserved-sample counters O_j — playouts
                          initiated but not yet backed up through the node
                          (``vl_mode="wu"``; DESIGN.md §15)
    parent    [N] i32     parent index (-1 for root / unallocated / freed)
    action    [N] i32     action taken from parent
    children  [N, A] i32  child indices (UNEXPANDED = -1)
    prior     [N, A] f32  child priors (uniform UCT / policy PUCT)
    terminal  [N] bool    node is a terminal state
    state     pytree      per-node domain state, leading dim N
    next_free scalar i32  bump-allocation high-water mark
    free_list [N] i32     LIFO stack of recycled row indices
    free_top  scalar i32  live depth of ``free_list``

Allocation contract (the free-list is what lets ``reroot`` recycle the
abandoned sibling subtrees instead of leaking rows across a serving
request's lifetime):

* ``alloc`` pops ``free_list[free_top - 1]`` when the stack is non-empty,
  else bumps ``next_free``.  Capacity is exhausted only when the stack is
  empty AND ``next_free == N`` — searches then stop expanding gracefully
  (``ok`` comes back False) instead of corrupting rows.
* ``release`` pushes rows onto the stack and resets their planes to the
  unallocated state (parent = -1, children = UNEXPANDED, uniform prior),
  so a recycled row is indistinguishable from a never-used one.
* ``compact``/``reroot`` rebuild the bookkeeping wholesale: live rows are
  renumbered densely from the (new) root, ``next_free`` drops to the live
  count and the stack empties — occupancy is bounded by the live subtree,
  not by search history.

A row is *live* iff it is the root or has ``parent >= 0`` (``live_mask``).
``ROOT`` is always row 0; ``compact`` preserves that invariant.

Dict-style ``arena["visits"]`` access still works for one release via
``__getitem__`` (with a ``DeprecationWarning``) so downstream code written
against the old ``Dict[str, Any]`` tree keeps running; new code should use
the attributes.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any, Dict

import jax
import jax.numpy as jnp

UNEXPANDED = -1
ROOT = 0

_FIELDS = ("visits", "value", "vloss", "unobs", "parent", "action",
           "children", "prior", "terminal", "state", "next_free",
           "free_list", "free_top")


@dataclasses.dataclass(frozen=True)
class TreeArena:
    """Flat SoA search tree (see module docstring for the plane layout)."""

    visits: Any
    value: Any
    vloss: Any
    unobs: Any
    parent: Any
    action: Any
    children: Any
    prior: Any
    terminal: Any
    state: Any
    next_free: Any
    free_list: Any
    free_top: Any

    # -- shape helpers (static ints, safe inside jit) -----------------------
    @property
    def max_nodes(self) -> int:
        return self.children.shape[-2]

    @property
    def num_actions(self) -> int:
        return self.children.shape[-1]

    def replace(self, **updates) -> "TreeArena":
        return dataclasses.replace(self, **updates)

    # -- deprecated dict-style access ---------------------------------------
    def __getitem__(self, key: str):
        if key not in _FIELDS:
            raise KeyError(key)
        warnings.warn(
            f"dict-style tree[{key!r}] access is deprecated; the tree is a "
            f"typed TreeArena now — use tree.{key} (repro.core.arena)",
            DeprecationWarning, stacklevel=2)
        return getattr(self, key)


jax.tree_util.register_pytree_node(
    TreeArena,
    lambda t: (tuple(getattr(t, f) for f in _FIELDS), None),
    lambda _, c: TreeArena(*c),
)


def init_arena(root_state, num_actions: int, max_nodes: int,
               root_terminal=False) -> TreeArena:
    """Fresh arena: root at row 0, every other row unallocated."""
    a = num_actions
    state = jax.tree_util.tree_map(
        lambda x: jnp.zeros((max_nodes,) + jnp.shape(x), jnp.asarray(x).dtype)
        .at[ROOT].set(x), root_state)
    return TreeArena(
        visits=jnp.zeros((max_nodes,), jnp.int32),
        value=jnp.zeros((max_nodes,), jnp.float32),
        vloss=jnp.zeros((max_nodes,), jnp.int32),
        unobs=jnp.zeros((max_nodes,), jnp.int32),
        parent=jnp.full((max_nodes,), UNEXPANDED, jnp.int32),
        action=jnp.full((max_nodes,), UNEXPANDED, jnp.int32),
        children=jnp.full((max_nodes, a), UNEXPANDED, jnp.int32),
        prior=jnp.full((max_nodes, a), 1.0 / a, jnp.float32),
        terminal=jnp.zeros((max_nodes,), bool)
        .at[ROOT].set(jnp.asarray(root_terminal, bool)),
        state=state,
        next_free=jnp.asarray(1, jnp.int32),
        free_list=jnp.zeros((max_nodes,), jnp.int32),
        free_top=jnp.asarray(0, jnp.int32),
    )


def live_mask(arena: TreeArena):
    """[N] bool — row is allocated (root, or has a parent)."""
    n = arena.max_nodes
    return (jnp.arange(n) == ROOT) | (arena.parent >= 0)


def capacity_left(arena: TreeArena):
    """Number of rows still allocatable (stack depth + untouched tail)."""
    return arena.free_top + (arena.max_nodes - arena.next_free)


def can_alloc(arena: TreeArena):
    return capacity_left(arena) > 0


def alloc(arena: TreeArena, take=True):
    """Allocate one row: ``(arena, row, ok)``.

    Pops the free-list LIFO first, else bumps ``next_free``.  ``ok`` is
    False (and ``row`` is the out-of-bounds sentinel ``max_nodes``, so
    ``mode="drop"`` scatters are no-ops) when ``take`` is False or the
    arena is full.  The caller writes the row's planes (parent/children/
    state/...) — ``alloc`` only moves the bookkeeping.
    """
    n = arena.max_nodes
    take = jnp.asarray(take, bool)
    ok = take & can_alloc(arena)
    use_stack = ok & (arena.free_top > 0)
    stack_row = arena.free_list[jnp.maximum(arena.free_top - 1, 0)]
    row = jnp.where(use_stack, stack_row, arena.next_free)
    row = jnp.where(ok, row, n).astype(jnp.int32)
    arena = arena.replace(
        next_free=arena.next_free + (ok & ~use_stack).astype(jnp.int32),
        free_top=arena.free_top - use_stack.astype(jnp.int32))
    return arena, row, ok


def release(arena: TreeArena, rows, mask=True):
    """Push rows onto the free-list and reset their planes.

    ``rows`` [K] i32 with ``mask`` [K] bool selecting which entries are
    real.  Contract: masked rows must be live, non-root, and distinct —
    releasing the root or double-releasing is a caller bug (not checked
    on-device).  After release the rows read as unallocated: parent = -1,
    children all UNEXPANDED, uniform prior, zeroed stats/state.
    """
    n, a = arena.max_nodes, arena.num_actions
    rows = jnp.atleast_1d(jnp.asarray(rows, jnp.int32))
    k = rows.shape[0]
    mask = jnp.broadcast_to(jnp.asarray(mask, bool), (k,))
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
    pos = jnp.where(mask, arena.free_top + rank, n)
    widx = jnp.where(mask, rows, n)
    zeros_k = jnp.zeros((k,), jnp.int32)
    state = jax.tree_util.tree_map(
        lambda buf: buf.at[widx].set(
            jnp.zeros((k,) + buf.shape[1:], buf.dtype), mode="drop"),
        arena.state)
    return arena.replace(
        visits=arena.visits.at[widx].set(zeros_k, mode="drop"),
        value=arena.value.at[widx].set(jnp.zeros((k,)), mode="drop"),
        vloss=arena.vloss.at[widx].set(zeros_k, mode="drop"),
        unobs=arena.unobs.at[widx].set(zeros_k, mode="drop"),
        parent=arena.parent.at[widx].set(zeros_k + UNEXPANDED, mode="drop"),
        action=arena.action.at[widx].set(zeros_k + UNEXPANDED, mode="drop"),
        children=arena.children.at[widx].set(
            jnp.full((k, a), UNEXPANDED, jnp.int32), mode="drop"),
        prior=arena.prior.at[widx].set(
            jnp.full((k, a), 1.0 / a, jnp.float32), mode="drop"),
        terminal=arena.terminal.at[widx].set(
            jnp.zeros((k,), bool), mode="drop"),
        state=state,
        free_list=arena.free_list.at[pos].set(rows, mode="drop"),
        free_top=arena.free_top + mask.sum().astype(jnp.int32),
    )


def compact(arena: TreeArena, keep, new_root=ROOT) -> TreeArena:
    """Dense renumbering: kept rows pack to the front, ``new_root`` -> row 0.

    ``keep`` [N] bool (``new_root`` is kept implicitly); other kept rows
    keep their relative order at rows 1..n_live-1.  Child/parent indices
    are remapped; pointers at dropped rows become UNEXPANDED.  The free
    bookkeeping resets: ``next_free = n_live``, empty stack — compaction IS
    the recycling step, every dropped row is allocatable again.
    """
    n = arena.max_nodes
    idx = jnp.arange(n)
    new_root = jnp.asarray(new_root, jnp.int32)
    is_nr = idx == new_root
    keep = jnp.asarray(keep, bool) | is_nr
    others = keep & ~is_nr
    newidx = jnp.where(is_nr, 0, jnp.cumsum(others.astype(jnp.int32)))
    n_live = 1 + others.sum().astype(jnp.int32)
    # src[j] = old index of the row that lands at j (j < n_live)
    src = jnp.zeros((n,), jnp.int32).at[
        jnp.where(keep, newidx, n)].set(idx.astype(jnp.int32), mode="drop")
    dst_live = idx < n_live
    remap = jnp.where(keep, newidx, UNEXPANDED).astype(jnp.int32)

    def gather(plane, fill):
        out = plane[src]
        fill = jnp.asarray(fill, out.dtype)
        return jnp.where(jnp.reshape(dst_live, (n,) + (1,) * (out.ndim - 1)),
                         out, fill)

    ch = gather(arena.children, UNEXPANDED)
    ch = jnp.where(ch >= 0, remap[jnp.maximum(ch, 0)], UNEXPANDED)
    pr = gather(arena.parent, UNEXPANDED)
    pr = jnp.where(pr >= 0, remap[jnp.maximum(pr, 0)], UNEXPANDED)
    pr = pr.at[ROOT].set(UNEXPANDED)
    state = jax.tree_util.tree_map(lambda p: gather(p, 0), arena.state)
    return arena.replace(
        visits=gather(arena.visits, 0),
        value=gather(arena.value, 0.0),
        vloss=gather(arena.vloss, 0),
        unobs=gather(arena.unobs, 0),
        parent=pr,
        action=gather(arena.action, UNEXPANDED).at[ROOT].set(UNEXPANDED),
        children=ch,
        prior=gather(arena.prior, 1.0 / arena.num_actions),
        terminal=gather(arena.terminal, False),
        state=state,
        next_free=n_live,
        free_list=jnp.zeros((n,), jnp.int32),
        free_top=jnp.asarray(0, jnp.int32),
    )


def reroot_ok(arena: TreeArena, action):
    """True when the committed child exists — rerooting onto it keeps a
    non-trivial subtree.  Callers gate on this; ``reroot`` with a missing
    child degrades to compacting the whole live tree under the old root."""
    return arena.children[ROOT, jnp.asarray(action, jnp.int32)] >= 0


def reroot(arena: TreeArena, action) -> TreeArena:
    """Promote root child ``action`` to row 0 and recycle everything else.

    Reachability from the new root is computed with parent-pointer doubling
    (ceil(log2 N) + 1 rounds of ``reach |= reach[link]; link = link[link]``),
    then ``compact`` renumbers the subtree densely — ``next_free`` falls to
    the subtree size, so long request lifetimes stay bounded by the live
    tree, not by cumulative search history (the §14 recycling contract).
    """
    n = arena.max_nodes
    child = arena.children[ROOT, jnp.asarray(action, jnp.int32)]
    nr = jnp.where(child >= 0, child, ROOT).astype(jnp.int32)
    idx = jnp.arange(n, dtype=jnp.int32)
    link = jnp.where(arena.parent >= 0, arena.parent, idx)

    def body(_, c):
        reach, link = c
        return reach | reach[link], link[link]

    rounds = int(math.ceil(math.log2(max(n, 2)))) + 1
    reach, _ = jax.lax.fori_loop(0, rounds, body, (idx == nr, link))
    return compact(arena, reach & live_mask(arena), nr)


def arena_stats(arena: TreeArena) -> Dict[str, Any]:
    """Device-side occupancy summary — no host sync, safe inside jit."""
    return {
        "live": live_mask(arena).sum().astype(jnp.int32),
        "next_free": arena.next_free,
        "free_top": arena.free_top,
        "capacity_left": capacity_left(arena).astype(jnp.int32),
    }
