"""DEPRECATED shim — use ``repro.search``:

    search(domain, SearchConfig(method="root", budget=b, lanes=workers,
                                params=sp), rng)

The canonical implementation lives in ``repro.search.strategies``; the new
API returns a normalized ``SearchResult`` instead of this shim's legacy
(root-stats dict, stats) pair (DESIGN.md §6 migration table).
"""
from __future__ import annotations

import warnings
from typing import Tuple

import jax.numpy as jnp

from repro.core import stages as S


def run_root_parallel(domain, sp: S.SearchParams, budget: int, workers: int,
                      rng) -> Tuple[dict, dict]:
    """Returns (combined root stats {action_visits, action_value}, stats)."""
    warnings.warn(
        "run_root_parallel is deprecated; use repro.search.search(domain, "
        "SearchConfig(method='root', lanes=workers, ...), rng)",
        DeprecationWarning, stacklevel=2)
    from repro.search.api import SearchConfig, search
    res = search(domain, SearchConfig(method="root", budget=budget,
                                      lanes=workers, params=sp), rng)
    return ({"action_visits": res.action_visits,
             "action_value": res.action_value},
            {"playouts": res.stats["playouts_completed"]})


def root_parallel_action(combined) -> jnp.ndarray:
    return jnp.argmax(combined["action_visits"])
