"""Root parallelization / Ensemble UCT — the §IV baseline (Chaslot; Fern&Lewis).

``workers`` independent sequential searches (no sharing, zero communication),
root statistics summed at the end.  Perfect playout-speedup, but each worker
only sees budget/workers playouts — strength saturates (Soejima et al.).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import stages as S
from repro.core.sequential import run_sequential
from repro.core.tree import ROOT


def run_root_parallel(domain, sp: S.SearchParams, budget: int, workers: int,
                      rng) -> Tuple[dict, dict]:
    """Returns (combined root stats {action_visits, action_value}, stats)."""
    per = -(-budget // workers)

    def one(r):
        tree, _ = run_sequential(domain, sp, per, r)
        ch = tree["children"][ROOT]
        valid = ch >= 0
        idx = jnp.maximum(ch, 0)
        n = jnp.where(valid, tree["visits"][idx], 0)
        w = jnp.where(valid, tree["value"][idx], 0.0)
        return n, w

    ns, ws = jax.vmap(one)(jax.random.split(rng, workers))
    return ({"action_visits": ns.sum(0), "action_value": ws.sum(0)},
            {"playouts": jnp.int32(per * workers)})


def root_parallel_action(combined) -> jnp.ndarray:
    return jnp.argmax(combined["action_visits"])
