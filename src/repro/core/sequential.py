"""DEPRECATED shim — use ``repro.search``:

    search(domain, SearchConfig(method="sequential", budget=b, params=sp), rng)

The canonical implementation lives in ``repro.search.strategies``; this
wrapper preserves the seed repo's ``run_sequential`` signature and return
shape for one release (DESIGN.md §6 migration table).
"""
from __future__ import annotations

import warnings
from typing import Tuple

from repro.core import stages as S
from repro.core.tree import Tree


def run_sequential(domain, sp: S.SearchParams, budget: int, rng,
                   max_nodes: int = 0) -> Tuple[Tree, dict]:
    warnings.warn(
        "run_sequential is deprecated; use repro.search.search(domain, "
        "SearchConfig(method='sequential', ...), rng)",
        DeprecationWarning, stacklevel=2)
    from repro.search.api import SearchConfig, search
    res = search(domain, SearchConfig(method="sequential", budget=budget,
                                      max_nodes=max_nodes, params=sp), rng)
    return res.tree, {"playouts": res.stats["playouts_completed"],
                      "values": res.extras["values"]}
