"""Sequential MCTS baseline — the paper's Fig. 1 flow (S→E→P→B per iteration).

This is the strength reference: every parallelization's strength-speedup and
search overhead are measured against this at equal budget.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import stages as S
from repro.core.tree import Tree, init_tree


def run_sequential(domain, sp: S.SearchParams, budget: int, rng,
                   max_nodes: int = 0) -> Tuple[Tree, dict]:
    tree = init_tree(domain, max_nodes or budget + 2)
    valid = jnp.asarray(True)

    def it(tree, rng_t):
        tree, sel = S.select_one(tree, sp, valid)
        tree, exp = S.expand_one(tree, domain, sp, sel)
        po = S.playout_wave(
            domain, sp,
            jax.tree_util.tree_map(lambda x: x[None], exp), rng_t)
        tree = S.backup_wave(tree, po)
        return tree, po["value"][0]

    tree, values = jax.lax.scan(it, tree, jax.random.split(rng, budget))
    return tree, {"playouts": jnp.int32(budget), "values": values}
