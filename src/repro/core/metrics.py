"""Scalability metrics (paper §II definitions 1 & 2, §III-B overheads)."""
from __future__ import annotations

from typing import Callable, Dict, Sequence

import numpy as np


def playout_speedup(t_seq: float, t_par: float) -> float:
    """Definition 1: wall-time speedup at equal playout budget."""
    return t_seq / max(t_par, 1e-12)


def strength(actions: Sequence[int], optimal: int) -> float:
    """Fraction of runs recommending the optimal root action."""
    a = np.asarray(list(actions))
    return float((a == optimal).mean())


def strength_speedup(seq_strength: float, par_strength: float) -> float:
    """Definition 2 proxy: strength retention at equal budget (1.0 = perfect)."""
    return par_strength / max(seq_strength, 1e-12)


def search_overhead(seq_curve: Dict[int, float], par_curve: Dict[int, float],
                    target: float) -> float:
    """SO = budget_par(target) / budget_seq(target), interpolated on
    strength-vs-budget curves. SO = 1 means no wasted playouts; > 1 means the
    parallel search needs proportionally more playouts (paper §III-B)."""
    def budget_for(curve):
        bs = np.array(sorted(curve))
        ss = np.array([curve[b] for b in bs])
        if ss.max() < target:
            return float("inf")
        i = int(np.argmax(ss >= target))
        if i == 0:
            return float(bs[0])
        # linear interpolation in log-budget
        b0, b1, s0, s1 = bs[i - 1], bs[i], ss[i - 1], ss[i]
        if s1 == s0:
            return float(b1)
        f = (target - s0) / (s1 - s0)
        return float(np.exp(np.log(b0) + f * (np.log(b1) - np.log(b0))))

    return budget_for(par_curve) / budget_for(seq_curve)


def duplicate_rate(duplicates: int, playouts: int) -> float:
    """In-flight duplicate-selection fraction — the direct, per-run search
    overhead signal (bounded by pipeline depth; grows with threads in tree
    parallelization)."""
    return duplicates / max(playouts, 1)
