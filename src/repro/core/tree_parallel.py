"""DEPRECATED shim — use ``repro.search``:

    search(domain, SearchConfig(method="tree", budget=b, lanes=threads,
                                params=sp), rng)

The canonical implementation lives in ``repro.search.strategies``
(DESIGN.md §6 migration table).
"""
from __future__ import annotations

import warnings
from typing import Tuple

from repro.core import stages as S
from repro.core.tree import Tree


def run_tree_parallel(domain, sp: S.SearchParams, budget: int, threads: int,
                      rng, max_nodes: int = 0) -> Tuple[Tree, dict]:
    warnings.warn(
        "run_tree_parallel is deprecated; use repro.search.search(domain, "
        "SearchConfig(method='tree', lanes=threads, ...), rng)",
        DeprecationWarning, stacklevel=2)
    from repro.search.api import SearchConfig, search
    res = search(domain, SearchConfig(method="tree", budget=budget,
                                      lanes=threads, max_nodes=max_nodes,
                                      params=sp), rng)
    return res.tree, {"playouts": res.stats["playouts_completed"],
                      "duplicates": res.stats["duplicates"]}
