"""Tree parallelization with virtual loss — the §IV baseline (Chaslot et al.).

Synchronous shared-tree parallelism: per round, ``threads`` trajectories are
selected (with virtual loss), expanded, played out in parallel, and backed up
together.  Staleness grows with ``threads`` (every trajectory in a round is
selected before ANY of the round's backups) — this is the search-overhead
regime the paper's pipeline bounds by its fixed in-flight window.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import stages as S
from repro.core.tree import Tree, init_tree


def run_tree_parallel(domain, sp: S.SearchParams, budget: int, threads: int,
                      rng, max_nodes: int = 0) -> Tuple[Tree, dict]:
    rounds = -(-budget // threads)
    tree = init_tree(domain, max_nodes or rounds * threads + 2)

    def round_fn(tree, rng_t):
        tree, sels = S.select_wave(tree, sp, threads, jnp.asarray(True))
        tree, exps = S.expand_wave(tree, domain, sp, sels)
        po = S.playout_wave(domain, sp, exps, rng_t)
        tree = S.backup_wave(tree, po)
        return tree, {"dup": sels["dup"].sum()}

    tree, stats = jax.lax.scan(round_fn, tree, jax.random.split(rng, rounds))
    return tree, {"playouts": jnp.int32(rounds * threads),
                  "duplicates": stats["dup"].sum()}
