"""UCT / PUCT child scoring — reference jnp path + Pallas-kernel dispatch.

Paper eq. (1):  UCT_j = w_j / n_j + C_p * sqrt(ln(n) / n_j)

Virtual loss (in-flight decorrelation, §IV related work / DESIGN §2):
    n_j^eff = n_j + vl_j
    w_j^eff = w_j - vl_weight * vl_j     (pessimistic in-flight estimate)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-1e30)


def uct_scores(child_n, child_w, child_vl, parent_n, cp, *, vl_weight=1.0,
               prior=None, puct=False):
    """All inputs per-child [..., A]; parent_n broadcastable. fp32 scores."""
    n_eff = (child_n + child_vl).astype(jnp.float32)
    w_eff = child_w - vl_weight * child_vl.astype(jnp.float32)
    pn = jnp.maximum(parent_n.astype(jnp.float32), 1.0)
    q = w_eff / jnp.maximum(n_eff, 1.0)
    if puct:
        assert prior is not None
        explore = prior * jnp.sqrt(pn)[..., None] / (1.0 + n_eff)
    else:
        explore = jnp.sqrt(jnp.log(pn)[..., None] / jnp.maximum(n_eff, 1.0))
    scores = q + cp * explore
    # unvisited & not in flight -> must-explore (paper: UCT = inf)
    return jnp.where(n_eff < 0.5, jnp.float32(1e30), scores)


def uct_argmax(child_n, child_w, child_vl, parent_n, cp, *, vl_weight=1.0,
               prior=None, puct=False, valid=None, use_pallas=False,
               interpret=False):
    """Best child index along the last axis. ``valid`` masks illegal slots."""
    if use_pallas and not puct:
        from repro.kernels.uct_select import ops as uops
        return uops.uct_argmax(child_n, child_w, child_vl, parent_n,
                               cp=cp, vl_weight=vl_weight,
                               valid=valid, interpret=interpret)
    s = uct_scores(child_n, child_w, child_vl, parent_n, cp,
                   vl_weight=vl_weight, prior=prior, puct=puct)
    if valid is not None:
        s = jnp.where(valid, s, NEG_INF)
    return jnp.argmax(s, axis=-1).astype(jnp.int32)
