"""UCT / PUCT child scoring — reference jnp path + Pallas-kernel dispatch.

Paper eq. (1):  UCT_j = w_j / n_j + C_p * sqrt(ln(n) / n_j)

Two in-flight decorrelation modes (``vl_mode``, DESIGN.md §15):

``"loss"`` — classic virtual loss (§IV related work / DESIGN §2):
    n_j^eff = n_j + vl_j
    w_j^eff = w_j - vl_weight * vl_j     (pessimistic in-flight estimate)
Q is *corrupted* while playouts are in flight — the price of the simple
single-plane bookkeeping.

``"wu"`` — WU-UCT (arXiv 1810.11755): track initiated-but-incomplete
playouts as an unobserved-sample count O_j that widens only exploration:
    Q_j       = w_j / max(n_j, 1)                      (completed stats only)
    explore_j = sqrt(ln(n_p + O_p) / max(n_j + O_j, 1))
Q is bit-identical whether 0 or 1000 playouts are in flight through j.

Must-explore ordering (intended, both modes, ref == Pallas bit-for-bit):
an *idle* unvisited child (effective count < 0.5 — loss: N+vl, wu: N+O)
gets the ``1e30`` sentinel and always wins; an *in-flight* unvisited child
scores finitely (loss: ``-vl_weight + cp*explore``; wu: ``0 + cp*explore``)
so lanes spread over idle siblings first.  Sentinel ties resolve to the
lowest index — both the jnp path and the kernel use first-max ``argmax``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-1e30)

VL_MODES = ("loss", "wu")


def uct_scores(child_n, child_w, child_vl, parent_n, cp, *, vl_weight=1.0,
               prior=None, puct=False, child_o=None, vl_mode="loss"):
    """All inputs per-child [..., A]; parent_n broadcastable. fp32 scores.

    ``vl_mode="loss"`` reads ``child_vl`` and ignores ``child_o``;
    ``"wu"`` reads ``child_o`` and ignores ``child_vl``.  ``parent_n`` must
    already include the same mode's in-flight count (N_p + vl_p or N_p + O_p
    — callers own that sum so lockstep can exclude a lane's own count).
    """
    if vl_mode not in VL_MODES:
        raise ValueError(f"vl_mode must be one of {VL_MODES}, got {vl_mode!r}")
    n = child_n.astype(jnp.float32)
    pn = jnp.maximum(parent_n.astype(jnp.float32), 1.0)
    if vl_mode == "wu":
        o = jnp.zeros_like(n) if child_o is None \
            else child_o.astype(jnp.float32)
        n_eff = n + o                       # widens exploration only
        q = child_w / jnp.maximum(n, 1.0)   # completed statistics only
    else:
        vl = child_vl.astype(jnp.float32)
        n_eff = n + vl
        q = (child_w - vl_weight * vl) / jnp.maximum(n_eff, 1.0)
    if puct:
        assert prior is not None
        explore = prior * jnp.sqrt(pn)[..., None] / (1.0 + n_eff)
    else:
        explore = jnp.sqrt(jnp.log(pn)[..., None] / jnp.maximum(n_eff, 1.0))
    scores = q + cp * explore
    # unvisited & not in flight -> must-explore (paper: UCT = inf)
    return jnp.where(n_eff < 0.5, jnp.float32(1e30), scores)


def uct_argmax(child_n, child_w, child_vl, parent_n, cp, *, vl_weight=1.0,
               prior=None, puct=False, valid=None, use_pallas=False,
               interpret=False, child_o=None, vl_mode="loss"):
    """Best child index along the last axis. ``valid`` masks illegal slots."""
    if use_pallas and not puct:
        from repro.kernels.uct_select import ops as uops
        return uops.uct_argmax(child_n, child_w, child_vl, parent_n,
                               cp=cp, vl_weight=vl_weight,
                               valid=valid, interpret=interpret,
                               child_o=child_o, vl_mode=vl_mode)
    s = uct_scores(child_n, child_w, child_vl, parent_n, cp,
                   vl_weight=vl_weight, prior=prior, puct=puct,
                   child_o=child_o, vl_mode=vl_mode)
    if valid is not None:
        s = jnp.where(valid, s, NEG_INF)
    return jnp.argmax(s, axis=-1).astype(jnp.int32)


def uct_argmax_running(child_n, child_w, child_vl, parent_n, parent_id, cp, *,
                       vl_weight=1.0, prior=None, puct=False, valid=None,
                       use_pallas=False, interpret=False, child_o=None,
                       vl_mode="loss"):
    """Running-assignment argmax over one wave's ``[lanes, A]`` level board
    (DESIGN.md §16): lanes are assigned IN ORDER, and lane k scores with the
    in-flight counts already incremented by the picks of lanes ``0..k-1``
    that share k's parent (``parent_id``, the node whose children row lane k
    is scoring) at this same level.  One call still serves the whole wave —
    the Pallas path is a single launch with a sequential row walk — but
    co-located lanes spread over viable children instead of stacking.

    The running delta joins the mode's in-flight plane before the shared
    scoring formula: in "loss" mode it rides ``child_vl`` (affecting both Q
    and the effective count), in "wu" mode it rides ``child_o`` (widening
    exploration only).  ``parent_n`` is NOT adjusted — earlier lanes'
    presence at the parent is already counted by the caller's per-level
    plane.  A lane whose ``valid`` row is all-False contributes nothing and
    returns index 0.  At ``lanes == 1`` the delta is identically zero, so
    the result is bit-for-bit equal to ``uct_argmax``.
    """
    lanes, a = child_n.shape
    if valid is None:
        valid = jnp.ones((lanes, a), bool)
    if use_pallas and not puct:
        from repro.kernels.uct_select import ops as uops
        return uops.uct_argmax_running(
            child_n, child_w, child_vl, parent_n, parent_id,
            cp=cp, vl_weight=vl_weight, valid=valid, interpret=interpret,
            child_o=child_o, vl_mode=vl_mode)
    if child_o is None:
        child_o = jnp.zeros((lanes, a), jnp.int32)
    active = valid.any(axis=-1)                            # [lanes]
    same = parent_id[:, None] == parent_id[None, :]        # [lanes, lanes]
    iota_a = jnp.arange(a)

    def body(contrib, k):
        # contrib[m]: same-parent picks of lanes < k, keyed on lane m's slots
        d = contrib[k]
        if vl_mode == "wu":
            vl_k, o_k = child_vl[k], child_o[k] + d
        else:
            vl_k, o_k = child_vl[k] + d, child_o[k]
        pn_k = parent_n[k] if jnp.ndim(parent_n) else parent_n
        s = uct_scores(child_n[k], child_w[k], vl_k, pn_k, cp,
                       vl_weight=vl_weight,
                       prior=None if prior is None else prior[k],
                       puct=puct, child_o=o_k, vl_mode=vl_mode)
        s = jnp.where(valid[k], s, NEG_INF)
        pick = jnp.argmax(s).astype(jnp.int32)
        add = ((iota_a == pick) & active[k]).astype(contrib.dtype)
        contrib = contrib + jnp.where(
            (same[:, k] & active[k])[:, None], add[None, :], 0)
        return contrib, pick

    _, picks = jax.lax.scan(
        body, jnp.zeros((lanes, a), jnp.float32), jnp.arange(lanes))
    return picks
