"""DEPRECATED shim — use ``repro.search``:

    search(domain, SearchConfig(method="pipeline", budget=b, lanes=l,
                                params=sp), rng)

The paper's pipelined MCTS implementation lives in
``repro.search.strategies.pipeline`` (see DESIGN.md §2 for the design and
§6 for the migration table).  ``PipelineConfig``/``run_pipeline`` are kept
for one release so existing callers keep working.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Tuple

import jax

from repro.core import stages as S
from repro.core.tree import Tree


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Deprecated — use repro.search.SearchConfig(method="pipeline")."""

    budget: int = 256            # total playouts
    lanes: int = 1               # parallel playout stages (1 = linear pipeline)
    max_nodes: int = 0           # 0 -> budget + 2
    params: S.SearchParams = dataclasses.field(default_factory=S.SearchParams)

    @property
    def n_waves(self) -> int:
        return -(-self.budget // self.lanes)

    @property
    def nodes(self) -> int:
        return self.max_nodes or (self.n_waves * self.lanes + 2)


def run_pipeline(domain, cfg: PipelineConfig, rng) -> Tuple[Tree, Dict[str, Any]]:
    """Returns (final tree, stats). Fully jit-compatible."""
    warnings.warn(
        "run_pipeline is deprecated; use repro.search.search(domain, "
        "SearchConfig(method='pipeline', ...), rng)",
        DeprecationWarning, stacklevel=2)
    from repro.search.api import SearchConfig, search
    res = search(domain, SearchConfig(method="pipeline", budget=cfg.budget,
                                      lanes=cfg.lanes, max_nodes=cfg.max_nodes,
                                      params=cfg.params), rng)
    stats = {
        "duplicates": res.stats["duplicates"],
        "playouts": res.stats["playouts_completed"],
        "ticks": res.stats["ticks"],
        "mean_occupancy": res.extras["mean_occupancy"],
        "dup_per_tick": res.extras["dup_per_tick"],
    }
    return res.tree, stats


def run_pipeline_jit(domain, cfg: PipelineConfig, rng):
    return jax.jit(lambda r: run_pipeline(domain, cfg, r))(rng)
