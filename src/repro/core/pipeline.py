"""The paper's contribution: pipelined MCTS (linear + nonlinear).

Software-pipelined execution of the four OLT stages over in-flight waves
(DESIGN.md §2).  One scan tick co-schedules:

    tick t:   B(wave t-3) | P(wave t-2) | E(wave t-1) | S(wave t)

so K = 4 waves are in flight — the pipeline depth of Fig. 2.  A wave carries
``lanes`` trajectories: lanes == 1 reproduces the *linear* pipeline (Fig. 3);
lanes > 1 is the *nonlinear* pipeline with ``lanes`` parallel playout stages
(Fig. 5/6), mapped to batched/sharded NN or rollout evaluation on TPU.

Search overhead is bounded by the in-flight window: Select at tick t sees all
backups from waves <= t-3 (the ILD compromise of §V-A), unlike tree
parallelization where staleness grows with thread count.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import stages as S
from repro.core.tree import Tree, init_tree

PIPE_STAGES = 4          # S, E, P, B


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    budget: int = 256            # total playouts
    lanes: int = 1               # parallel playout stages (1 = linear pipeline)
    max_nodes: int = 0           # 0 -> budget + 2
    params: S.SearchParams = dataclasses.field(default_factory=S.SearchParams)

    @property
    def n_waves(self) -> int:
        return -(-self.budget // self.lanes)

    @property
    def nodes(self) -> int:
        return self.max_nodes or (self.n_waves * self.lanes + 2)


def run_pipeline(domain, cfg: PipelineConfig, rng) -> Tuple[Tree, Dict[str, Any]]:
    """Returns (final tree, stats). Fully jit-compatible."""
    sp = cfg.params
    lanes = cfg.lanes
    tree = init_tree(domain, cfg.nodes)
    n_ticks = cfg.n_waves + (PIPE_STAGES - 1)       # fill + drain

    init_carry = (
        tree,
        S.empty_selection(sp, lanes),                       # S -> E buffer
        S.empty_expansion(sp, lanes, domain),               # E -> P buffer
        S.empty_playout(sp, lanes, domain.num_actions),     # P -> B buffer
    )

    def tick(carry, inp):
        t, rng_t = inp
        tree, buf_se, buf_ep, buf_pb = carry
        # Backup stage — wave t-3 (oldest in flight)
        tree = S.backup_wave(tree, buf_pb)
        # Playout stage — wave t-2 (parallel lanes)
        new_pb = S.playout_wave(domain, sp, buf_ep, rng_t)
        # Expand stage — wave t-1
        tree, new_ep = S.expand_wave(tree, domain, sp, buf_se)
        # Select stage — wave t (masked during drain)
        wave_valid = t < cfg.n_waves
        tree, new_se = S.select_wave(tree, sp, lanes, wave_valid)
        stats = {
            "dup": new_se["dup"].sum(),
            "completed": buf_pb["valid"].sum(),
            "occupancy": (new_se["valid"].any().astype(jnp.int32)
                          + buf_se["valid"].any().astype(jnp.int32)
                          + buf_ep["valid"].any().astype(jnp.int32)
                          + buf_pb["valid"].any().astype(jnp.int32)),
        }
        return (tree, new_se, new_ep, new_pb), stats

    rngs = jax.random.split(rng, n_ticks)
    ts = jnp.arange(n_ticks)
    (tree, *_), stats = jax.lax.scan(tick, init_carry, (ts, rngs))
    out_stats = {
        "duplicates": stats["dup"].sum(),
        "playouts": stats["completed"].sum(),
        "ticks": jnp.int32(n_ticks),
        "mean_occupancy": stats["occupancy"].mean() / PIPE_STAGES,
        "dup_per_tick": stats["dup"],
    }
    return tree, out_stats


def run_pipeline_jit(domain, cfg: PipelineConfig, rng):
    return jax.jit(lambda r: run_pipeline(domain, cfg, r))(rng)
