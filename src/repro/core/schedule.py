"""Analytic pipeline-schedule model — reproduces the paper's Figs. 3, 4, 6.

Host-side (numpy) simulation of makespan for a 4-stage MCTS pipeline with
per-stage costs in T units and ``lanes`` replicated Playout servers:

  Fig. 3: costs (1,1,1,1), lanes 1, 4 trajectories   -> 7 T
  Fig. 4: costs (1,1,2,1), lanes 1, 4 trajectories   -> 11 T
  Fig. 6: costs (1,1,2,1), lanes 2, 4 trajectories   -> 8 T
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

STAGES = ("select", "expand", "playout", "backup")


def pipeline_makespan(n_items: int, costs: Sequence[float] = (1, 1, 1, 1),
                      lanes: int = 1) -> float:
    """Makespan of n_items trajectories through S→E→P→B.

    Serial stages process items in order; the Playout stage has ``lanes``
    identical servers (the paper's nonlinear parallel stage, which may finish
    items out of order — Backup is commutative so any completion order is
    consumed as it arrives).
    """
    cs, ce, cp, cb = costs
    s_free = e_free = b_free = 0.0
    p_free = np.zeros(lanes)
    s_done = np.zeros(n_items)
    e_done = np.zeros(n_items)
    p_done = np.zeros(n_items)
    for i in range(n_items):
        s_start = max(s_free, 0.0)
        s_done[i] = s_start + cs
        s_free = s_done[i]
        e_start = max(e_free, s_done[i])
        e_done[i] = e_start + ce
        e_free = e_done[i]
        lane = int(np.argmin(p_free))
        p_start = max(p_free[lane], e_done[i])
        p_done[i] = p_start + cp
        p_free[lane] = p_done[i]
    # backup consumes completions in arrival order (out-of-order OK)
    makespan = 0.0
    for t in np.sort(p_done):
        b_start = max(b_free, t)
        b_free = b_start + cb
        makespan = b_free
    return float(makespan)


def sequential_makespan(n_items: int, costs: Sequence[float] = (1, 1, 1, 1)) -> float:
    return float(n_items * sum(costs))


def steady_state_throughput(costs: Sequence[float] = (1, 1, 1, 1),
                            lanes: int = 1) -> float:
    """Trajectories per T unit once the pipeline is full (paper §V-C)."""
    cs, ce, cp, cb = costs
    bottleneck = max(cs, ce, cp / lanes, cb)
    return 1.0 / bottleneck


def occupancy_trace(n_items: int, costs: Sequence[float] = (1, 1, 1, 1),
                    lanes: int = 1, dt: float = 0.25) -> Tuple[np.ndarray, np.ndarray]:
    """(time grid, #busy PEs) — visualizes fill/drain (paper §V-B)."""
    cs, ce, cp, cb = costs
    intervals: List[Tuple[float, float]] = []
    s_free = e_free = b_free = 0.0
    p_free = np.zeros(lanes)
    p_done = np.zeros(n_items)
    for i in range(n_items):
        s0 = s_free
        s_free = s0 + cs
        intervals.append((s0, s_free))
        e0 = max(e_free, s_free)
        e_free = e0 + ce
        intervals.append((e0, e_free))
        lane = int(np.argmin(p_free))
        p0 = max(p_free[lane], e_free)
        p_free[lane] = p0 + cp
        p_done[i] = p_free[lane]
        intervals.append((p0, p_free[lane]))
    for t in np.sort(p_done):
        b0 = max(b_free, t)
        b_free = b0 + cb
        intervals.append((b0, b_free))
    end = max(e for _, e in intervals)
    grid = np.arange(0.0, end + dt, dt)
    busy = np.zeros_like(grid)
    for (a, b) in intervals:
        busy += ((grid >= a) & (grid < b)).astype(float)
    return grid, busy
