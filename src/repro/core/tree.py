"""Search-tree API — thin wrappers over the typed ``core.arena.TreeArena``.

The tree used to be a raw ``Dict[str, Any]`` pytree; it is now the typed
SoA arena (``repro.core.arena``) with a free-list so rows are recycled
across a serving request's lifetime.  This module keeps the historical
entry points (``init_tree`` / ``get_state`` / ``reroot`` /
``warm_start_root`` / ``check_consistency``) as thin wrappers; dict-style
``tree["visits"]`` still works for one release via the arena's
``__getitem__`` deprecation shim.

API change (DESIGN.md §14): ``reroot`` now returns the rerooted *arena*
(the committed child promoted to row 0, abandoned siblings recycled) —
serving carries the whole subtree across tokens.  The old stat-compacting
behaviour survives as ``root_carry`` (the ``RootCarry`` warm-start path).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.arena import (ROOT, UNEXPANDED, TreeArena, init_arena,
                              live_mask)
from repro.core.arena import reroot as _arena_reroot
from repro.core.arena import reroot_ok  # noqa: F401  (re-export)

Tree = TreeArena


def init_tree(domain, max_nodes: int) -> Tree:
    """Build the search tree for ``domain``.

    Starts cold (root = ``domain.root_state()``), then applies the optional
    cross-token warm-start hooks carried on the domain:

    * ``domain.root_warm``  — a ``RootCarry`` seeding the root's N/W/prior
      (statistic-level reuse, DESIGN.md §12);
    * ``domain.root_arena`` — a full carried ``TreeArena`` (same capacity)
      spliced in wholesale when ``domain.root_arena_alive`` (subtree-level
      reuse, DESIGN.md §14); when not alive the cold tree is used, making
      the empty carry bit-for-bit a cold search.
    """
    root_state = domain.root_state()
    tree = init_arena(root_state, domain.num_actions, max_nodes,
                      domain.is_terminal(root_state))
    warm = getattr(domain, "root_warm", None)
    if warm is not None:
        tree = warm_start_root(tree, warm)
    carried = getattr(domain, "root_arena", None)
    if carried is not None:
        alive = getattr(domain, "root_arena_alive", None)
        alive = jnp.asarray(True if alive is None else alive, bool)
        tree = jax.tree_util.tree_map(
            lambda c, f: jnp.where(
                jnp.reshape(alive, (1,) * jnp.ndim(f)), c, f), carried, tree)
    return tree


def empty_root_carry(num_actions: int) -> Dict[str, Any]:
    """The identity ``RootCarry``: warm-starting with it is bit-for-bit a
    cold search (zero visits, uniform prior — exactly ``init_tree``'s
    defaults), so freshly admitted serving slots just reset to this."""
    a = num_actions
    return {
        "visits": jnp.asarray(0, jnp.int32),
        "value": jnp.asarray(0.0, jnp.float32),
        "prior": jnp.full((a,), 1.0 / a, jnp.float32),
        "child_visits": jnp.zeros((a,), jnp.int32),
        "child_value": jnp.zeros((a,), jnp.float32),
    }


def root_carry(tree: Tree, action) -> Dict[str, Any]:
    """Compact the subtree under root child ``action`` into a ``RootCarry``
    (DESIGN.md §12): the chosen child's N/W, its stored prior row, and its
    children's N/W — the statistic-level warm start (``warm_start_root``).
    Unvisited slots fall back to the identity carry.  For full subtree
    reuse use ``reroot``, which keeps the whole arena."""
    a = num_actions(tree)
    c = tree.children[ROOT][action]
    has = c >= 0
    ci = jnp.maximum(c, 0)
    gch = tree.children[ci]                          # grandchildren [A]
    gvalid = (gch >= 0) & has
    gi = jnp.maximum(gch, 0)
    return {
        "visits": jnp.where(has, tree.visits[ci], 0).astype(jnp.int32),
        "value": jnp.where(has, tree.value[ci], 0.0).astype(jnp.float32),
        "prior": jnp.where(has, tree.prior[ci],
                           jnp.full((a,), 1.0 / a, jnp.float32)),
        "child_visits": jnp.where(gvalid, tree.visits[gi],
                                  0).astype(jnp.int32),
        "child_value": jnp.where(gvalid, tree.value[gi],
                                 0.0).astype(jnp.float32),
    }


def reroot(tree: Tree, action) -> Tree:
    """Promote root child ``action`` to the root and recycle the abandoned
    rows (``core.arena.reroot``).  Returns the rerooted arena — the next
    search's ready-made tree.  Note: carried ``terminal`` flags reflect the
    *previous* horizon; callers re-deriving the horizon (serving) refresh
    them against the new domain (DESIGN.md §14)."""
    return _arena_reroot(tree, action)


def warm_start_root(tree: Tree, carry: Dict[str, Any]) -> Tree:
    """Seed a fresh tree's root from a ``RootCarry`` (cross-token subtree
    reuse, DESIGN.md §12): root N/W start at the carried child's counts and
    the root prior blends the carried prior with the carried grandchild
    visit distribution — previously explored continuations start favoured
    (PUCT) instead of uniform.  ``warm_start_root(t, empty_root_carry(A))``
    is bit-for-bit the identity: ``(prior + 0) / (1 + 0) == prior``."""
    cv = carry["child_visits"].astype(jnp.float32)
    prior = (carry["prior"] + cv) / (1.0 + cv.sum())
    return tree.replace(
        visits=tree.visits.at[ROOT].set(carry["visits"].astype(jnp.int32)),
        value=tree.value.at[ROOT].set(carry["value"].astype(jnp.float32)),
        prior=tree.prior.at[ROOT].set(prior))


def max_nodes(tree: Tree) -> int:
    return tree.max_nodes


def num_actions(tree: Tree) -> int:
    return tree.num_actions


def get_state(tree: Tree, node):
    return jax.tree_util.tree_map(lambda x: x[node], tree.state)


def root_action_by_visits(tree: Tree):
    """Final move selection: most-visited root child (standard robust child)."""
    ch = tree.children[ROOT]
    n = jnp.where(ch >= 0, tree.visits[jnp.maximum(ch, 0)], -1)
    return jnp.argmax(n)


def root_child_stats(tree: Tree):
    ch = tree.children[ROOT]
    valid = ch >= 0
    idx = jnp.maximum(ch, 0)
    n = jnp.where(valid, tree.visits[idx], 0)
    w = jnp.where(valid, tree.value[idx], 0.0)
    return n, w, valid


def check_consistency(tree: Tree) -> Dict[str, Any]:
    """Invariant summary (tests): visit flow conservation, vloss drained,
    parent pointers live.  Fully device-side — 0-d bool/int arrays, no
    ``int()`` host round-trip, so it is safe to call inside traced code."""
    n = max_nodes(tree)
    idx = jnp.arange(n)
    alive = live_mask(tree)
    ok_vloss = (tree.vloss == 0).all()
    ok_unobs = (tree.unobs == 0).all()
    ch = tree.children[ROOT]
    child_sum = jnp.where(ch >= 0, tree.visits[jnp.maximum(ch, 0)], 0).sum()
    ok_flow = child_sum <= tree.visits[ROOT]
    nonroot = alive & (idx != ROOT)
    p = tree.parent
    ok_parent = jnp.where(
        nonroot,
        (p >= 0) & (p < n) & alive[jnp.clip(p, 0, n - 1)],
        True).all()
    return {"vloss_drained": ok_vloss, "unobs_drained": ok_unobs,
            "visit_flow": ok_flow, "parents_valid": ok_parent,
            "nodes": alive.sum()}
