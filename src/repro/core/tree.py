"""Structure-of-arrays MCTS search tree (device-resident, pure-functional).

The TPU analogue of the paper's lock-free shared tree: every mutation is a
scatter-add/scatter-set inside jit, so concurrent waves commute by
construction (backup is an add — order-independent, which is what makes the
paper's out-of-order nonlinear pipeline sound; see DESIGN.md §2).

Layout (N = max_nodes, A = num_actions):
    visits   [N] i32    visit count n_j
    value    [N] f32    reward sum  w_j
    vloss    [N] i32    virtual-loss counters (in-flight trajectories through j)
    parent   [N] i32    parent index (-1 for root)
    action   [N] i32    action taken from parent
    children [N, A] i32 child indices (UNEXPANDED = -1)
    prior    [N, A] f32 child priors (uniform for plain UCT, policy for PUCT)
    terminal [N] bool   node is a terminal state
    state    pytree     per-node domain state, leading dim N
    next_free scalar i32
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

UNEXPANDED = -1
ROOT = 0

Tree = Dict[str, Any]


def init_tree(domain, max_nodes: int) -> Tree:
    a = domain.num_actions
    root_state = domain.root_state()
    state = jax.tree_util.tree_map(
        lambda x: jnp.zeros((max_nodes,) + jnp.shape(x), jnp.asarray(x).dtype)
        .at[ROOT].set(x), root_state)
    tree = {
        "visits": jnp.zeros((max_nodes,), jnp.int32),
        "value": jnp.zeros((max_nodes,), jnp.float32),
        "vloss": jnp.zeros((max_nodes,), jnp.int32),
        "parent": jnp.full((max_nodes,), UNEXPANDED, jnp.int32),
        "action": jnp.full((max_nodes,), UNEXPANDED, jnp.int32),
        "children": jnp.full((max_nodes, a), UNEXPANDED, jnp.int32),
        "prior": jnp.full((max_nodes, a), 1.0 / a, jnp.float32),
        "terminal": jnp.zeros((max_nodes,), bool)
        .at[ROOT].set(domain.is_terminal(root_state)),
        "state": state,
        "next_free": jnp.asarray(1, jnp.int32),
    }
    warm = getattr(domain, "root_warm", None)
    if warm is not None:
        tree = warm_start_root(tree, warm)
    return tree


def empty_root_carry(num_actions: int) -> Dict[str, Any]:
    """The identity ``RootCarry``: warm-starting with it is bit-for-bit a
    cold search (zero visits, uniform prior — exactly ``init_tree``'s
    defaults), so freshly admitted serving slots just reset to this."""
    a = num_actions
    return {
        "visits": jnp.asarray(0, jnp.int32),
        "value": jnp.asarray(0.0, jnp.float32),
        "prior": jnp.full((a,), 1.0 / a, jnp.float32),
        "child_visits": jnp.zeros((a,), jnp.int32),
        "child_value": jnp.zeros((a,), jnp.float32),
    }


def reroot(tree: Tree, action) -> Dict[str, Any]:
    """Compact the subtree under root child ``action`` into a ``RootCarry``
    (DESIGN.md §12): the chosen child's N/W, its stored prior row, and its
    children's N/W.  After committing the child's token this is exactly the
    statistic set of the next search's root — carried across tokens as a
    warm start instead of searching cold.  Unvisited slots fall back to the
    identity carry (uniform prior, zero counts), so rerooting onto an
    unexpanded child degrades gracefully to cold."""
    a = num_actions(tree)
    c = tree["children"][ROOT][action]
    has = c >= 0
    ci = jnp.maximum(c, 0)
    gch = tree["children"][ci]                       # grandchildren [A]
    gvalid = (gch >= 0) & has
    gi = jnp.maximum(gch, 0)
    return {
        "visits": jnp.where(has, tree["visits"][ci], 0).astype(jnp.int32),
        "value": jnp.where(has, tree["value"][ci], 0.0).astype(jnp.float32),
        "prior": jnp.where(has, tree["prior"][ci],
                           jnp.full((a,), 1.0 / a, jnp.float32)),
        "child_visits": jnp.where(gvalid, tree["visits"][gi],
                                  0).astype(jnp.int32),
        "child_value": jnp.where(gvalid, tree["value"][gi],
                                 0.0).astype(jnp.float32),
    }


def warm_start_root(tree: Tree, carry: Dict[str, Any]) -> Tree:
    """Seed a fresh tree's root from a ``RootCarry`` (cross-token subtree
    reuse, DESIGN.md §12): root N/W start at the carried child's counts and
    the root prior blends the carried prior with the carried grandchild
    visit distribution — previously explored continuations start favoured
    (PUCT) instead of uniform.  ``warm_start_root(t, empty_root_carry(A))``
    is bit-for-bit the identity: ``(prior + 0) / (1 + 0) == prior``."""
    cv = carry["child_visits"].astype(jnp.float32)
    prior = (carry["prior"] + cv) / (1.0 + cv.sum())
    tree = dict(tree)
    tree["visits"] = tree["visits"].at[ROOT].set(
        carry["visits"].astype(jnp.int32))
    tree["value"] = tree["value"].at[ROOT].set(
        carry["value"].astype(jnp.float32))
    tree["prior"] = tree["prior"].at[ROOT].set(prior)
    return tree


def max_nodes(tree: Tree) -> int:
    return tree["visits"].shape[0]


def num_actions(tree: Tree) -> int:
    return tree["children"].shape[1]


def get_state(tree: Tree, node):
    return jax.tree_util.tree_map(lambda x: x[node], tree["state"])


def root_action_by_visits(tree: Tree):
    """Final move selection: most-visited root child (standard robust child)."""
    ch = tree["children"][ROOT]
    n = jnp.where(ch >= 0, tree["visits"][jnp.maximum(ch, 0)], -1)
    return jnp.argmax(n)


def root_child_stats(tree: Tree):
    ch = tree["children"][ROOT]
    valid = ch >= 0
    idx = jnp.maximum(ch, 0)
    n = jnp.where(valid, tree["visits"][idx], 0)
    w = jnp.where(valid, tree["value"][idx], 0.0)
    return n, w, valid


def check_consistency(tree: Tree) -> Dict[str, Any]:
    """Host-side invariants (tests): visit flow conservation, vloss drained."""
    nf = int(tree["next_free"])
    visits = tree["visits"][:nf]
    parent = tree["parent"][:nf]
    ok_vloss = bool((tree["vloss"] == 0).all())
    # each non-root node's visits accumulate into ancestors: root visits ==
    # number of completed backups; sum of root-children visits <= root visits
    ch = tree["children"][ROOT]
    child_idx = ch[ch >= 0]
    child_sum = int(tree["visits"][child_idx].sum()) if child_idx.size else 0
    ok_flow = child_sum <= int(visits[ROOT])
    ok_parent = bool((parent[1:] >= 0).all()) and bool((parent[1:] < nf).all())
    return {"vloss_drained": ok_vloss, "visit_flow": ok_flow,
            "parents_valid": ok_parent, "nodes": nf}
