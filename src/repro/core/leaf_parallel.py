"""Leaf parallelization — the §IV baseline (Chaslot et al.).

One trajectory at a time (sequential S/E), but ``workers`` playouts from the
same leaf in parallel; backup aggregates all of them.  No selection
staleness, but the information per playout is lower (all rollouts share one
leaf) and S/E stay serial — limited strength- and playout-speedup.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import stages as S
from repro.core.tree import Tree, init_tree, max_nodes


def run_leaf_parallel(domain, sp: S.SearchParams, budget: int, workers: int,
                      rng, max_nodes_: int = 0) -> Tuple[Tree, dict]:
    iters = -(-budget // workers)
    tree = init_tree(domain, max_nodes_ or iters + 2)

    def it(tree, rng_t):
        tree, sel = S.select_one(tree, sp, jnp.asarray(True))
        tree, exp = S.expand_one(tree, domain, sp, sel)
        values = jax.vmap(lambda r: domain.playout(exp["state"], r))(
            jax.random.split(rng_t, workers))
        v_sum = values.sum()
        # aggregate backup: n += workers, w += sum(values) along the path
        paths = exp["path"]
        mask = paths >= 0
        idx = jnp.maximum(paths, 0)
        tree = dict(tree)
        tree["visits"] = tree["visits"].at[idx].add(mask * workers)
        tree["value"] = tree["value"].at[idx].add(jnp.where(mask, v_sum, 0.0))
        tree["vloss"] = tree["vloss"].at[idx].add(-mask.astype(jnp.int32))
        return tree, None

    tree, _ = jax.lax.scan(it, tree, jax.random.split(rng, iters))
    return tree, {"playouts": jnp.int32(iters * workers)}
