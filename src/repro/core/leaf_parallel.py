"""DEPRECATED shim — use ``repro.search``:

    search(domain, SearchConfig(method="leaf", budget=b, lanes=workers,
                                params=sp), rng)

The canonical implementation lives in ``repro.search.strategies``.  Note the
trailing parameter is now spelled ``max_nodes`` (the seed's ``max_nodes_``
inconsistency is gone; DESIGN.md §6 migration table).
"""
from __future__ import annotations

import warnings
from typing import Tuple

from repro.core import stages as S
from repro.core.tree import Tree


def run_leaf_parallel(domain, sp: S.SearchParams, budget: int, workers: int,
                      rng, max_nodes: int = 0) -> Tuple[Tree, dict]:
    warnings.warn(
        "run_leaf_parallel is deprecated; use repro.search.search(domain, "
        "SearchConfig(method='leaf', lanes=workers, ...), rng)",
        DeprecationWarning, stacklevel=2)
    from repro.search.api import SearchConfig, search
    res = search(domain, SearchConfig(method="leaf", budget=budget,
                                      lanes=workers, max_nodes=max_nodes,
                                      params=sp), rng)
    return res.tree, {"playouts": res.stats["playouts_completed"]}
