# NOTE: run_pipeline/run_sequential (and the other run_* runners) are
# deprecated shims — new code should import from repro.search instead.
from repro.core.pipeline import PipelineConfig, run_pipeline  # noqa: F401
from repro.core.sequential import run_sequential  # noqa: F401
from repro.core.stages import SearchParams  # noqa: F401
from repro.core.tree import init_tree, root_action_by_visits  # noqa: F401
