# Building blocks for repro.search (tree, stages, uct, schedule, domains).
# The seed-era run_* entry points and their deprecation shims are gone —
# use repro.search (DESIGN.md §6).
from repro.core.stages import SearchParams  # noqa: F401
from repro.core.tree import init_tree, root_action_by_visits  # noqa: F401
