"""MCTS-guided LM decoding domain — the modern instantiation of the paper's
Playout stage (NN evaluation dominates; see DESIGN.md §2 assumption 1).

State = token buffer + length.  Actions = the top-A next tokens under the
policy LM.  Playout = greedy rollout of ``rollout_len`` tokens; reward =
exp(mean logprob) in (0, 1].  Priors = renormalized top-A policy probs (PUCT).

This generic (uncached) domain re-evaluates the prefix per call — correct and
simple, used by core tests and examples.  The production serving path
(repro.serving.mcts_decode) batches playouts across lanes, which is exactly
the paper's parallel-playout-stage load balancing.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig, get_family


@dataclasses.dataclass(frozen=True)
class LMDecodeDomain:
    cfg: ModelConfig
    params: Any
    prompt: Any                       # [buf_len] int32 (padded buffer OK)
    num_actions: int = 4
    search_depth: int = 8             # max new tokens explored by the tree
    rollout_len: int = 4
    temperature: float = 1.0
    prompt_len: Any = None            # optional (traced) true prefix length;
                                      # None -> prompt.shape[0].  Lets batched
                                      # serving share one padded buffer shape
                                      # across requests of different lengths.

    def __post_init__(self):
        object.__setattr__(self, "_fam", get_family(self.cfg))

    @property
    def max_len(self) -> int:
        return int(self.prompt.shape[0]) + self.search_depth + self.rollout_len

    def _plen(self):
        if self.prompt_len is None:
            return jnp.int32(self.prompt.shape[0])
        return jnp.asarray(self.prompt_len, jnp.int32)

    def root_state(self):
        toks = jnp.zeros((self.max_len,), jnp.int32)
        toks = jax.lax.dynamic_update_slice(toks, self.prompt.astype(jnp.int32), (0,))
        return {"toks": toks, "len": self._plen()}

    # -- internals ----------------------------------------------------------
    def _last_logits(self, toks, ln):
        logits = self._fam.logits_fn(self.cfg, self.params, toks[None])
        return logits[0, ln - 1].astype(jnp.float32) / self.temperature

    def _topk(self, state):
        logits = self._last_logits(state["toks"], state["len"])
        return jax.lax.top_k(logits, self.num_actions)

    # -- domain API ----------------------------------------------------------
    def step(self, state, action):
        _, top_toks = self._topk(state)
        tok = top_toks[action]
        toks = state["toks"].at[state["len"]].set(tok.astype(jnp.int32), mode="drop")
        return {"toks": toks, "len": state["len"] + 1}

    def is_terminal(self, state):
        return state["len"] >= self._plen() + self.search_depth

    def playout(self, state, rng):
        """Greedy rollout; reward = exp(mean next-token logprob)."""
        def body(c, _):
            toks, ln, acc = c
            logits = self._last_logits(toks, ln)
            logp = jax.nn.log_softmax(logits)
            tok = jnp.argmax(logits).astype(jnp.int32)
            acc = acc + logp[tok]
            toks = toks.at[ln].set(tok, mode="drop")
            return (toks, ln + 1, acc), None

        (_, _, acc), _ = jax.lax.scan(
            body, (state["toks"], state["len"], jnp.float32(0.0)),
            None, length=self.rollout_len)
        return jnp.exp(acc / self.rollout_len)

    def priors(self, state):
        top_vals, _ = self._topk(state)
        return jax.nn.softmax(top_vals)
