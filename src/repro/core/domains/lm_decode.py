"""MCTS-guided LM decoding domain — the modern instantiation of the paper's
Playout stage (NN evaluation dominates; see DESIGN.md §2 assumption 1).

State = token buffer + length.  Actions = the top-A next tokens under the
policy LM.  Playout = greedy rollout of ``rollout_len`` tokens; reward =
exp(mean logprob) in (0, 1].  Priors = renormalized top-A policy probs (PUCT).

Two variants (DESIGN.md §10):

* ``LMDecodeDomain`` — generic (uncached): every step/playout re-evaluates
  the whole prefix.  Correct and simple, used by core tests and examples,
  and the parity oracle for the cached variant.
* ``CachedLMDecodeDomain`` — KV-cache-aware: the prompt is prefilled ONCE
  per search (at ``root_state``) and the per-sequence cache is threaded
  through the tree state, so every expand costs one incremental token and
  every playout ``rollout_len`` incremental tokens instead of full-prefix
  forwards.  Uses the family's ``prefill_fn``/``step_fn`` when implemented
  (dense: ``kernels/decode_attention``), else the pure-JAX fallback in
  ``models.base`` (correct for every family, just uncached).

The production serving path (repro.serving.mcts_decode) batches playouts
across lanes, which is exactly the paper's parallel-playout-stage load
balancing.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig, get_family, seq_prefill, seq_step


@dataclasses.dataclass(frozen=True)
class LMDecodeDomain:
    cfg: ModelConfig
    params: Any
    prompt: Any                       # [buf_len] int32 (padded buffer OK)
    num_actions: int = 4
    search_depth: int = 8             # max new tokens explored by the tree
    rollout_len: int = 4
    temperature: float = 1.0
    prompt_len: Any = None            # optional (traced) true prefix length;
                                      # None -> prompt.shape[0].  Lets batched
                                      # serving share one padded buffer shape
                                      # across requests of different lengths.
    root_warm: Any = None             # optional RootCarry (core.tree): seeds
                                      # the search root's N/W/prior from the
                                      # previous token's rerooted subtree
                                      # (cross-token reuse, DESIGN.md §12).
                                      # None searches cold.
    root_arena: Any = None            # optional carried TreeArena (same
                                      # capacity as the search's max_nodes):
                                      # the previous token's rerooted subtree,
                                      # spliced in wholesale (full subtree
                                      # reuse, DESIGN.md §14).  None (or
                                      # root_arena_alive False) searches cold.
    root_arena_alive: Any = None      # (traced) bool gating root_arena per
                                      # slot; None means alive.

    def __post_init__(self):
        object.__setattr__(self, "_fam", get_family(self.cfg))

    @property
    def max_len(self) -> int:
        return int(self.prompt.shape[0]) + self.search_depth + self.rollout_len

    def _plen(self):
        if self.prompt_len is None:
            return jnp.int32(self.prompt.shape[0])
        return jnp.asarray(self.prompt_len, jnp.int32)

    def root_state(self):
        toks = jnp.zeros((self.max_len,), jnp.int32)
        toks = jax.lax.dynamic_update_slice(toks, self.prompt.astype(jnp.int32), (0,))
        return {"toks": toks, "len": self._plen()}

    # -- internals ----------------------------------------------------------
    def _last_logits(self, toks, ln):
        logits = self._fam.logits_fn(self.cfg, self.params, toks[None])
        return logits[0, ln - 1].astype(jnp.float32) / self.temperature

    def _topk(self, state):
        logits = self._last_logits(state["toks"], state["len"])
        return jax.lax.top_k(logits, self.num_actions)

    # -- domain API ----------------------------------------------------------
    def step(self, state, action):
        _, top_toks = self._topk(state)
        tok = top_toks[action]
        toks = state["toks"].at[state["len"]].set(tok.astype(jnp.int32), mode="drop")
        return {"toks": toks, "len": state["len"] + 1}

    def is_terminal(self, state):
        return state["len"] >= self._plen() + self.search_depth

    def playout(self, state, rng):
        """Greedy rollout; reward = exp(mean next-token logprob)."""
        def body(c, _):
            toks, ln, acc = c
            logits = self._last_logits(toks, ln)
            logp = jax.nn.log_softmax(logits)
            tok = jnp.argmax(logits).astype(jnp.int32)
            acc = acc + logp[tok]
            toks = toks.at[ln].set(tok, mode="drop")
            return (toks, ln + 1, acc), None

        (_, _, acc), _ = jax.lax.scan(
            body, (state["toks"], state["len"], jnp.float32(0.0)),
            None, length=self.rollout_len)
        return jnp.exp(acc / self.rollout_len)

    def priors(self, state):
        top_vals, _ = self._topk(state)
        return jax.nn.softmax(top_vals)


@dataclasses.dataclass(frozen=True)
class CachedLMDecodeDomain(LMDecodeDomain):
    """KV-cache-aware variant: same decisions as ``LMDecodeDomain`` (up to
    float noise), amortized compute.

    State = ``{"len", "cache", "logits"}`` — the cache IS the token history
    (per-layer K/V rows for the dense family; a token buffer for the generic
    fallback) and ``logits`` are the next-token logits the prefix implies,
    so ``step``/``priors`` need no model call for the *current* position and
    each appended token costs one ``seq_step``.  The prompt is prefilled
    exactly once, in ``root_state`` — shared by every expand and playout of
    the search (the tree's structure-of-arrays state fans it out).

    Memory note: every tree node (and pipeline buffer lane) carries a full
    cache copy ``[L, max_len, Hkv, D]`` — the classic KV-cache trade of
    memory for compute, scaled here by tree capacity (DESIGN.md §10).

    Commit-time KV splice (DESIGN.md §12): when ``root_cache``/``root_logits``
    are set, ``root_state`` returns them verbatim instead of prefilling —
    the serving searcher advances the previous token's root row by one
    ``seq_step`` at commit time and splices it back in, so a request's
    prompt is prefilled once per *lifetime* instead of once per token.
    """

    root_cache: Any = None            # optional spliced root KV cache (must
                                      # match seq_prefill's layout at
                                      # max_len); None prefills the prompt
    root_logits: Any = None           # next-token logits paired with
                                      # root_cache

    def root_state(self):
        if self.root_cache is not None:
            return {"len": self._plen(), "cache": self.root_cache,
                    "logits": self.root_logits}
        toks = jnp.zeros((self.max_len,), jnp.int32)
        toks = jax.lax.dynamic_update_slice(toks, self.prompt.astype(jnp.int32), (0,))
        logits, cache = seq_prefill(self.cfg, self.params, toks, self._plen())
        return {"len": self._plen(), "cache": cache, "logits": logits}

    # -- internals ----------------------------------------------------------
    def _state_logits(self, state):
        return state["logits"].astype(jnp.float32) / self.temperature

    def _topk(self, state):
        return jax.lax.top_k(self._state_logits(state), self.num_actions)

    # -- domain API ----------------------------------------------------------
    def step(self, state, action):
        _, top_toks = self._topk(state)
        tok = top_toks[action].astype(jnp.int32)
        logits, cache = seq_step(self.cfg, self.params, state["cache"], tok,
                                 state["len"])
        return {"len": state["len"] + 1, "cache": cache, "logits": logits}

    def playout(self, state, rng):
        """Greedy rollout; reward = exp(mean next-token logprob).  Matches
        the uncached playout token-for-token: iteration t consumes the
        logits the previous step produced instead of a full forward."""
        def body(c, _):
            logits, cache, ln, acc = c
            scaled = logits.astype(jnp.float32) / self.temperature
            logp = jax.nn.log_softmax(scaled)
            tok = jnp.argmax(scaled).astype(jnp.int32)
            acc = acc + logp[tok]
            logits, cache = seq_step(self.cfg, self.params, cache, tok, ln)
            return (logits, cache, ln + 1, acc), None

        (_, _, _, acc), _ = jax.lax.scan(
            body, (state["logits"], state["cache"], state["len"],
                   jnp.float32(0.0)),
            None, length=self.rollout_len)
        return jnp.exp(acc / self.rollout_len)

    # is_terminal and priors are inherited: both consume only state["len"]
    # and _topk, which reads the cached logits.
