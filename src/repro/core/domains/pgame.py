"""Synthetic P-game trees — the standard domain for UCT scalability studies
(Kocsis & Szepesvári; Segal "On the Scalability of Parallel UCT").

A uniform tree of branching ``num_actions`` and depth ``game_depth``; each
edge carries a pseudo-random value in [0,1] derived from a 32-bit path hash.
Terminal reward = (binary) path sum exceeding a threshold, or (smooth) the
normalized path sum.  Ground-truth optimal root actions are enumerable on the
host for small trees (``enumerate_root_values``), giving an exact strength
metric.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

FNV = np.uint32(16777619)
MIX = np.uint32(2654435761)


def _hash_step(h, a):
    return ((h ^ (a.astype(jnp.uint32) + 1)) * FNV).astype(jnp.uint32)


def _edge_value(h):
    return (h * MIX).astype(jnp.uint32).astype(jnp.float32) / jnp.float32(2 ** 32)


@dataclasses.dataclass(frozen=True)
class PGameDomain:
    num_actions: int = 4
    game_depth: int = 8
    threshold: float = 0.5
    binary_reward: bool = True
    seed: int = 0

    def root_state(self):
        return {"hash": jnp.uint32(np.uint32(2166136261) ^ np.uint32(self.seed)),
                "depth": jnp.int32(0), "accum": jnp.float32(0.0)}

    def step(self, state, action):
        h = _hash_step(state["hash"], action)
        return {"hash": h, "depth": state["depth"] + 1,
                "accum": state["accum"] + _edge_value(h)}

    def is_terminal(self, state):
        return state["depth"] >= self.game_depth

    def playout(self, state, rng):
        """Uniform-random rollout to terminal; reward in [0, 1]."""
        def body(i, c):
            h, d, acc, r = c
            r, sub = jax.random.split(r)
            a = jax.random.randint(sub, (), 0, self.num_actions)
            do = i >= d            # rollout covers levels [depth, game_depth)
            h2 = _hash_step(h, a)
            acc2 = acc + _edge_value(h2)
            h = jnp.where(do, h2, h)
            acc = jnp.where(do, acc2, acc)
            return (h, d, acc, r)

        h, d, acc, _ = jax.lax.fori_loop(
            0, self.game_depth, body,
            (state["hash"], state["depth"], state["accum"], rng))
        total = acc / self.game_depth
        if self.binary_reward:
            return (total > self.threshold).astype(jnp.float32)
        return jnp.clip(total, 0.0, 1.0)

    def priors(self, state):
        return jnp.full((self.num_actions,), 1.0 / self.num_actions, jnp.float32)


def enumerate_root_values(domain: PGameDomain) -> np.ndarray:
    """Exact E[reward | root action, uniform play] per action (host, numpy).

    Feasible for num_actions**game_depth up to a few million.
    """
    a, d = domain.num_actions, domain.game_depth
    h0 = np.uint32(2166136261) ^ np.uint32(domain.seed)
    hashes = np.array([h0], dtype=np.uint32)
    accums = np.array([0.0], dtype=np.float64)
    first_action = np.zeros(1, dtype=np.int64)
    for level in range(d):
        acts = np.arange(a, dtype=np.uint32)
        h = ((hashes[:, None] ^ (acts[None, :] + 1)) * FNV).astype(np.uint32)
        ev = ((h * MIX).astype(np.uint32)).astype(np.float64) / float(2 ** 32)
        accums = (accums[:, None] + ev).reshape(-1)
        hashes = h.reshape(-1)
        first_action = (np.arange(a)[None, :] + 0 * first_action[:, None]).reshape(-1) \
            if level == 0 else np.repeat(first_action, a)
    total = accums / d
    if domain.binary_reward:
        rewards = (total > domain.threshold).astype(np.float64)
    else:
        rewards = np.clip(total, 0.0, 1.0)
    out = np.zeros(a)
    for i in range(a):
        out[i] = rewards[first_action == i].mean()
    return out


def optimal_root_action(domain: PGameDomain) -> int:
    return int(np.argmax(enumerate_root_values(domain)))
