from repro.data.pipeline import (  # noqa: F401
    DataConfig, Prefetcher, make_batch_iterator, synthetic_batch,
)
