"""Deterministic synthetic data pipeline with packing + host prefetch.

Every batch is derived from (seed, step, host_id) so restarts reproduce the
exact token stream (checkpoint/restart correctness is testable), and each
host generates only its shard (data-parallel input pipeline).

``Prefetcher`` overlaps host-side batch synthesis with device compute via a
background thread + bounded queue — the input-pipeline analogue of the
paper's pipeline overlap.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.models.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch_size: int = 8
    seq_len: int = 128
    n_hosts: int = 1
    host_id: int = 0
    pack_documents: bool = True
    mean_doc_len: int = 64


def _rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))


def _packed_tokens(rng: np.random.Generator, b: int, s: int, vocab: int,
                   mean_doc: int) -> np.ndarray:
    """Documents of ~geometric length packed back-to-back with EOS=0."""
    toks = rng.integers(1, vocab, size=(b, s), dtype=np.int32)
    if mean_doc > 0:
        # place EOS boundaries with prob 1/mean_doc
        eos = rng.random((b, s)) < (1.0 / mean_doc)
        toks = np.where(eos, 0, toks)
    return toks


def synthetic_batch(model_cfg: ModelConfig, data_cfg: DataConfig,
                    step: int) -> Dict[str, np.ndarray]:
    """Batch for any family; labels are next-token shifted."""
    rng = _rng(data_cfg, step)
    b, s = data_cfg.batch_size, data_cfg.seq_len
    if model_cfg.family == "vlm":
        p = model_cfg.n_patches
        s_txt = s - p
        toks = _packed_tokens(rng, b, s_txt, model_cfg.vocab_size,
                              data_cfg.mean_doc_len if data_cfg.pack_documents else 0)
        labels = np.concatenate(
            [np.zeros((b, p), np.int32), np.roll(toks, -1, axis=1)], axis=1)
        patches = rng.normal(size=(b, p, model_cfg.frontend_dim)).astype(np.float32)
        return {"patches": patches, "tokens": toks, "labels": labels}
    toks = _packed_tokens(rng, b, s, model_cfg.vocab_size,
                          data_cfg.mean_doc_len if data_cfg.pack_documents else 0)
    labels = np.roll(toks, -1, axis=1)
    batch = {"tokens": toks, "labels": labels}
    if model_cfg.family == "whisper":
        batch["frames"] = rng.normal(
            size=(b, model_cfg.enc_seq, model_cfg.d_model)).astype(np.float32)
    return batch


def make_batch_iterator(model_cfg: ModelConfig, data_cfg: DataConfig,
                        start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield synthetic_batch(model_cfg, data_cfg, step)
        step += 1


class Prefetcher:
    """Bounded background prefetch (double buffering by default)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
