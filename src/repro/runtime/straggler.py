"""Straggler mitigation for the nonlinear pipeline's playout lanes.

The paper's parallel playout stages may complete out of order (§V-C); backup
is commutative, so a straggling lane can simply be dropped from its wave and
re-queued without corrupting the tree (its virtual loss is still removed via
the masked backup of the same path).  This module provides the host-side
policy used by the serving engine and by the training-loop collective layer
(deadline-based wave commit), plus a simulator to quantify throughput-vs-
drop-rate under heavy-tailed lane latencies.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    deadline_factor: float = 3.0       # x median lane latency
    min_commit_frac: float = 0.75      # never commit a wave below this fill
    requeue: bool = True


def wave_commit_mask(latencies: np.ndarray, policy: StragglerPolicy
                     ) -> Tuple[np.ndarray, float]:
    """latencies [lanes] -> (keep mask, commit time).

    Lanes beyond deadline are dropped (re-queued into the next wave); the
    wave commits at the slowest KEPT lane.
    """
    med = float(np.median(latencies))
    deadline = policy.deadline_factor * med
    keep = latencies <= deadline
    if keep.mean() < policy.min_commit_frac:
        # deadline too aggressive for this wave: keep the fastest fraction
        k = int(np.ceil(policy.min_commit_frac * len(latencies)))
        thresh = np.partition(latencies, k - 1)[k - 1]
        keep = latencies <= thresh
    commit_time = float(latencies[keep].max()) if keep.any() else float(latencies.min())
    return keep, commit_time


def simulate_throughput(policy: StragglerPolicy, lanes: int, waves: int,
                        seed: int = 0, tail: float = 0.1) -> Dict[str, float]:
    """Heavy-tailed lane latency model: lognormal body + pareto stragglers."""
    rng = np.random.default_rng(seed)
    total_time = 0.0
    completed = 0
    dropped = 0
    for _ in range(waves):
        lat = rng.lognormal(0.0, 0.25, lanes)
        stragglers = rng.random(lanes) < tail
        lat = np.where(stragglers, lat * (1 + rng.pareto(1.5, lanes) * 3), lat)
        keep, t = wave_commit_mask(lat, policy)
        total_time += t
        completed += int(keep.sum())
        dropped += int((~keep).sum())
    baseline_time = 0.0
    rng = np.random.default_rng(seed)
    for _ in range(waves):
        lat = rng.lognormal(0.0, 0.25, lanes)
        stragglers = rng.random(lanes) < tail
        lat = np.where(stragglers, lat * (1 + rng.pareto(1.5, lanes) * 3), lat)
        baseline_time += float(lat.max())
    return {
        "throughput": completed / total_time,
        "baseline_throughput": (waves * lanes) / baseline_time,
        "drop_rate": dropped / (waves * lanes),
        "speedup": (completed / total_time) / ((waves * lanes) / baseline_time),
    }
