"""Fault-tolerant training loop: watchdog, NaN guards, restart-from-checkpoint.

Designed for the 1000+-node regime:
* every state mutation goes through the checkpoint manager (async, atomic);
* a heartbeat watchdog thread detects hangs (e.g. a dead collective) and
  raises in the main thread so the scheduler can restart the process;
* restart path = resume from latest committed step with the SAME data stream
  (synthetic pipeline is (seed, step)-deterministic) — loss curves are
  bitwise-continuable;
* NaN/inf loss steps are skipped (params/opt not committed) with a counter —
  the standard large-run guard against data poison / overflow blips;
* failure injection hooks let tests exercise all of the above determinist-
  ically (kill at step N, NaN at step M, stall at step K).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


class SimulatedFailure(RuntimeError):
    pass


class WatchdogTimeout(RuntimeError):
    pass


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    watchdog_s: float = 600.0
    max_nan_skips: int = 10
    # failure injection (tests)
    fail_at_step: Optional[int] = None
    nan_at_step: Optional[int] = None
    stall_at_step: Optional[int] = None


class Heartbeat:
    """Raises WatchdogTimeout if no beat arrives within ``timeout_s``."""

    def __init__(self, timeout_s: float):
        self.timeout_s = timeout_s
        self._last = time.monotonic()
        self._stop = threading.Event()
        self.expired = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def beat(self):
        self._last = time.monotonic()
        if self.expired.is_set():
            raise WatchdogTimeout("heartbeat expired")

    def _run(self):
        while not self._stop.wait(min(self.timeout_s / 4, 1.0)):
            if time.monotonic() - self._last > self.timeout_s:
                self.expired.set()
                return

    def stop(self):
        self._stop.set()


class TrainerLoop:
    """step_fn(params, opt_state, batch) -> (params, opt_state, metrics).

    ``batch_iter`` may be an iterator OR a factory ``step -> iterator``; the
    factory form re-seeks the (deterministic) data stream after a restore so
    restarted runs consume exactly the batches the lost run would have.
    """

    def __init__(self, step_fn: Callable, params: Any, opt_state: Any,
                 batch_iter, ft: FTConfig, shardings: Any = None):
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self._batch_src = batch_iter
        self.batch_iter = None if callable(batch_iter) else batch_iter
        self.ft = ft
        self.shardings = shardings
        self.ckpt = CheckpointManager(ft.ckpt_dir, keep=ft.keep,
                                      every=ft.ckpt_every)
        self.step = 0
        self.nan_skips = 0
        self.history: list = []

    # -- state (de)hydration --------------------------------------------
    def _state(self):
        return {"params": self.params, "opt": self.opt_state}

    def try_restore(self) -> bool:
        step, state = self.ckpt.restore_latest(self._state(), self.shardings)
        if state is None:
            return False
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = step
        return True

    # -- main loop --------------------------------------------------------
    def run(self, n_steps: int, heartbeat: Optional[Heartbeat] = None) -> Dict:
        if self.batch_iter is None:
            self.batch_iter = self._batch_src(self.step)
        target = self.step + n_steps
        while self.step < target:
            batch = next(self.batch_iter)
            if self.ft.stall_at_step == self.step and heartbeat is not None:
                time.sleep(self.ft.watchdog_s * 1.5)
            if self.ft.fail_at_step == self.step:
                raise SimulatedFailure(f"injected failure at step {self.step}")
            new_params, new_opt, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            if self.ft.nan_at_step == self.step:
                loss = float("nan")
            if not np.isfinite(loss):
                # skip the update: keep previous params/opt
                self.nan_skips += 1
                if self.nan_skips > self.ft.max_nan_skips:
                    raise RuntimeError("too many non-finite steps")
                self.step += 1
                continue
            self.params, self.opt_state = new_params, new_opt
            self.step += 1
            self.history.append(loss)
            self.ckpt.maybe_save(self.step, self._state())
            if heartbeat is not None:
                heartbeat.beat()
        self.ckpt.wait()
        return {"step": self.step, "losses": self.history,
                "nan_skips": self.nan_skips}


def run_with_restarts(make_loop: Callable[[], TrainerLoop], n_steps: int,
                      max_restarts: int = 3) -> Dict:
    """Process-level restart simulation: on failure, rebuild the loop (fresh
    'process'), restore from the latest checkpoint, continue."""
    restarts = 0
    loop = make_loop()
    loop.try_restore()
    while True:
        try:
            remaining = n_steps - loop.step
            if remaining <= 0:
                return {"step": loop.step, "restarts": restarts,
                        "losses": loop.history}
            out = loop.run(remaining)
            return {"step": out["step"], "restarts": restarts,
                    "losses": out["losses"]}
        except (SimulatedFailure, WatchdogTimeout):
            restarts += 1
            if restarts > max_restarts:
                raise
            loop = make_loop()
            loop.try_restore()
