"""Elastic scaling: resume a checkpoint on a different mesh.

The checkpoint stores full (unsharded) leaves; ``reshard_state`` places them
onto the new mesh with shardings re-resolved from the same logical-axis
rules — so a job can shrink from 2 pods to 1 (or grow) and continue, which is
the practical response to losing a pod in a 1000+-node run.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax

from repro.models.base import ModelConfig, get_family
from repro.parallel.sharding import DEFAULT_RULES, make_shardings


def state_shardings(cfg: ModelConfig, state: Dict[str, Any], mesh,
                    rules=None) -> Dict[str, Any]:
    """Shardings for a {'params':…, 'opt':…} training state on ``mesh``."""
    fam = get_family(cfg)
    axes = fam.param_axes(cfg)
    out: Dict[str, Any] = {}
    out["params"] = make_shardings(axes, state["params"], mesh, rules)
    opt_axes = {}
    for k, v in state["opt"].items():
        opt_axes[k] = None if k == "step" else axes
    out["opt"] = make_shardings(opt_axes, state["opt"], mesh, rules)
    return out


def reshard_state(cfg: ModelConfig, state: Dict[str, Any], new_mesh,
                  rules=None) -> Dict[str, Any]:
    sh = state_shardings(cfg, state, new_mesh, rules or DEFAULT_RULES)
    return jax.tree_util.tree_map(jax.device_put, state, sh)


def shrink_mesh(mesh, lost_devices, axis: str = "batch"):
    """A 1-D mesh over ``mesh``'s devices minus ``lost_devices`` — the search
    analogue of ``reshard_state``: after a host loss the elastic driver
    re-places subsequent work onto the surviving devices only (DESIGN.md
    §13).  Returns ``None`` when no device survives."""
    from repro.parallel.compat import mesh_from_devices
    lost = set(lost_devices)
    keep = [d for d in mesh.devices.flat if d not in lost]
    if not keep:
        return None
    return mesh_from_devices(keep, axis)
