from repro.runtime.ft import FTConfig, TrainerLoop  # noqa: F401
from repro.runtime.straggler import StragglerPolicy  # noqa: F401
