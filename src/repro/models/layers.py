"""Shared neural-net building blocks (pure functions over param dicts).

Conventions
-----------
* activations are ``cfg.jdtype`` (bf16), norm/softmax accumulate in fp32;
* attention layouts are [B, S, H, D];
* per-layer params may be stacked on a leading ``layers`` axis for lax.scan.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.base import ModelConfig


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, in_axis: int = 0):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype, std: float = 0.02):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def init_norm(cfg: ModelConfig, key, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), cfg.jdtype), "bias": jnp.zeros((d,), cfg.jdtype)}
    return {"scale": jnp.ones((d,), cfg.jdtype)}


def apply_norm(cfg: ModelConfig, p, x):
    if "bias" in p:
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# ---------------------------------------------------------------------------
# rotary position embedding (supports partial rotary, stablelm-2 style)
# ---------------------------------------------------------------------------
def rope_freqs(cfg: ModelConfig, positions, rot_dim: Optional[int] = None):
    """positions [..., S] -> (cos, sin) each [..., S, rot_dim/2] fp32."""
    rot = rot_dim or int(cfg.head_dim * cfg.rope_frac)
    rot = max(rot - rot % 2, 2)
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, rope_frac: float = 1.0):
    """x [B,S,H,D]; cos/sin [B,S,R/2] or [S,R/2]. Rotates leading R dims of D."""
    r2 = cos.shape[-1]
    rot, x_pass = x[..., : 2 * r2], x[..., 2 * r2:]
    x1, x2 = rot[..., :r2], rot[..., r2:]
    if cos.ndim == 2:  # [S, R/2] -> broadcast over batch and heads
        cos_b = cos[None, :, None, :]
        sin_b = sin[None, :, None, :]
    else:              # [B, S, R/2]
        cos_b = cos[:, :, None, :]
        sin_b = sin[:, :, None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = x1f * cos_b - x2f * sin_b
    o2 = x2f * cos_b + x1f * sin_b
    out = jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if x_pass.shape[-1] else out


# ---------------------------------------------------------------------------
# attention (reference path; kernel path lives in repro.kernels.*.ops)
# ---------------------------------------------------------------------------
def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def sdpa(q, k, v, *, causal: bool, q_offset=0, bias=None, logits_soft_cap: float = 0.0):
    """Reference scaled-dot-product attention.

    q [B,Sq,H,D], k/v [B,Sk,Hkv,D]; GQA via kv-head repetition.
    ``q_offset`` positions q rows at kv index offset (decode / chunked prefill).
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    scale = 1.0 / math.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    if logits_soft_cap > 0.0:
        logits = logits_soft_cap * jnp.tanh(logits / logits_soft_cap)
    if bias is not None:
        logits = logits + bias
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(k.shape[1])[None, :]
        mask = qpos >= kpos
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _fa_bias(qi, ki, blk_q, blk_k, sk, q_offset, causal):
    """Additive mask bias [blk_q, blk_k] f32 (0 keep / -1e30 drop).

    Additive form (not jnp.where on the scores) so differentiation of the
    surrounding scans never saves a batch-broadcast boolean mask as a
    residual — add's transpose is residual-free.
    """
    kpos = ki * blk_k + jnp.arange(blk_k)
    keep = (kpos[None, :] < sk) * jnp.ones((blk_q, 1), bool)
    if causal:
        qpos = qi * blk_q + jnp.arange(blk_q) + q_offset
        keep = keep & (qpos[:, None] >= kpos[None, :])
    return jnp.where(keep, 0.0, -1e30).astype(jnp.float32)


def _fa_scores(qb, kb, scale, cap):
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                   preferred_element_type=jnp.float32) * scale
    if cap > 0.0:
        s = cap * jnp.tanh(s / cap)
    return s


def _blocked_fwd(q, k, v, causal, q_offset, blk_q, blk_k, cap):
    """Returns (out [B,Sq,H,Dv], lse [B,Hkv,g,Sq]). Supports Dv != Dqk (MLA)."""
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    dv = v.shape[-1]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    pad_q, pad_k = (-sq) % blk_q, (-sk) % blk_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    nq, nk = (sq + pad_q) // blk_q, (sk + pad_k) // blk_k
    qs = qp.reshape(b, nq, blk_q, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)
    ks = kp.reshape(b, nk, blk_k, hkv, d).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(b, nk, blk_k, hkv, dv).transpose(1, 0, 2, 3, 4)

    def q_block(_, qi_qb):
        qi, qb = qi_qb
        m0 = jnp.full((b, hkv, g, blk_q), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, blk_q), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, blk_q, dv), jnp.float32)

        def kv_block(carry, ki_kb):
            m, l, acc = carry
            ki, kb, vb = ki_kb
            s = _fa_scores(qb, kb, scale, cap)
            s = s + _fa_bias(qi, ki, blk_q, blk_k, sk, q_offset, causal)[None, None, None]
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0),
                                      (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out.transpose(0, 3, 1, 2, 4).astype(q.dtype), lse)

    _, (outs, lses) = jax.lax.scan(q_block, None, (jnp.arange(nq), qs))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq + pad_q, h, dv)[:, :sq]
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, hkv, g, sq + pad_q)[..., :sq]
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _blocked_attention_core(q, k, v, causal, q_offset, blk_q, blk_k, cap):
    return _blocked_fwd(q, k, v, causal, q_offset, blk_q, blk_k, cap)[0]


def _core_fwd(q, k, v, causal, q_offset, blk_q, blk_k, cap):
    out, lse = _blocked_fwd(q, k, v, causal, q_offset, blk_q, blk_k, cap)
    return out, (q, k, v, out, lse)


def _core_bwd(causal, q_offset, blk_q, blk_k, cap, res, dout):
    """Flash backward: recompute p blockwise from (q, k, v, lse); no stored
    probability matrices (the TPU flash-bwd dataflow, in XLA form)."""
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    dv = v.shape[-1]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    pad_q, pad_k = (-sq) % blk_q, (-sk) % blk_k
    pq = lambda x: jnp.pad(x, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else x
    pk = lambda x: jnp.pad(x, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else x
    qp, dop, op = pq(q), pq(dout), pq(out)
    kp, vp = pk(k), pk(v)
    nq, nk = (sq + pad_q) // blk_q, (sk + pad_k) // blk_k
    delta = jnp.sum(dop.astype(jnp.float32) * op.astype(jnp.float32), -1)  # [B,Sq,H]
    delta = delta.reshape(b, nq, blk_q, hkv, g).transpose(1, 0, 3, 4, 2)
    lse_p = jnp.pad(lse, ((0, 0), (0, 0), (0, 0), (0, pad_q))) if pad_q else lse
    lse_b = lse_p.reshape(b, hkv, g, nq, blk_q).transpose(3, 0, 1, 2, 4)
    qs = qp.reshape(b, nq, blk_q, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)
    dos = dop.reshape(b, nq, blk_q, hkv, g, dv).transpose(1, 0, 2, 3, 4, 5)
    ks = kp.reshape(b, nk, blk_k, hkv, d).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(b, nk, blk_k, hkv, dv).transpose(1, 0, 2, 3, 4)

    def q_block(carry, inp):
        dk_acc, dv_acc = carry                     # [B, Sk_pad, Hkv, D] f32
        qi, qb, dob, lse_i, delta_i = inp

        def kv_block(c2, inp2):
            dq_b, dk_a, dv_a = c2
            ki, kb, vb = inp2
            bias = _fa_bias(qi, ki, blk_q, blk_k, sk, q_offset, causal)
            s = _fa_scores(qb, kb, scale, cap) + bias[None, None, None]
            p = jnp.exp(s - lse_i[..., None])                       # [B,h,g,q,k]
            dv_blk = jnp.einsum("bhgqk,bqhgd->bkhd", p, dob.astype(jnp.float32))
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dob, vb,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - delta_i[..., None])                      # wrt capped s
            if cap > 0.0:
                ds = ds * (1.0 - jnp.square((s - bias[None, None, None]) / cap))
            ds = ds * (bias[None, None, None] > -1.0)               # re-mask
            dq_b = dq_b + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kb,
                                     preferred_element_type=jnp.float32) * scale
            dk_blk = jnp.einsum("bhgqk,bqhgd->bkhd", ds,
                                qb.astype(jnp.float32)) * scale
            dk_a = jax.lax.dynamic_update_slice(
                dk_a, jax.lax.dynamic_slice(
                    dk_a, (0, ki * blk_k, 0, 0), (b, blk_k, hkv, d)) + dk_blk,
                (0, ki * blk_k, 0, 0))
            dv_a = jax.lax.dynamic_update_slice(
                dv_a, jax.lax.dynamic_slice(
                    dv_a, (0, ki * blk_k, 0, 0), (b, blk_k, hkv, dv)) + dv_blk,
                (0, ki * blk_k, 0, 0))
            return (dq_b, dk_a, dv_a), None

        dq0 = jnp.zeros((b, blk_q, hkv, g, d), jnp.float32)
        (dq_b, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_block, (dq0, dk_acc, dv_acc), (jnp.arange(nk), ks, vs))
        return (dk_acc, dv_acc), dq_b

    dkv0 = (jnp.zeros((b, sk + pad_k, hkv, d), jnp.float32),
            jnp.zeros((b, sk + pad_k, hkv, dv), jnp.float32))
    (dk, dv), dqs = jax.lax.scan(q_block, dkv0,
                                 (jnp.arange(nq), qs, dos, lse_b, delta))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq + pad_q, h, d)[:, :sq]
    return (dq.astype(q.dtype), dk[:, :sk].astype(k.dtype),
            dv[:, :sk].astype(v.dtype))


_blocked_attention_core.defvjp(_core_fwd, _core_bwd)


def blocked_attention(q, k, v, *, causal: bool, q_offset=0, blk_q=256,
                      blk_k=1024, logits_soft_cap: float = 0.0):
    """Memory-efficient attention in pure jnp: double-blocked online softmax
    with a flash *backward* (custom VJP; p recomputed blockwise — never
    materializes [Sq, Sk] in fwd or bwd).

    Used by the full-size configs so the dry-run's lowered HLO has flash-like
    memory behaviour; the Pallas kernel replaces it 1:1 on real TPU.
    GQA is computed grouped (no kv-head repetition).
    """
    sq, sk = q.shape[1], k.shape[1]
    return _blocked_attention_core(q, k, v, causal, int(q_offset),
                                   min(blk_q, sq), min(blk_k, sk),
                                   float(logits_soft_cap))


def attention(cfg: ModelConfig, q, k, v, *, causal: bool, q_offset=0,
              kv_valid_len=None, logits_soft_cap: float = 0.0):
    """Dispatch: Pallas flash kernels on TPU, blocked or materialized jnp
    reference elsewhere.

    ``kv_valid_len`` [B] masks a pre-allocated KV cache beyond the filled
    prefix (decode path).
    """
    if cfg.use_pallas and kv_valid_len is None and q.shape[1] > 1:
        from repro.kernels.flash_attention import ops as fa
        return fa.flash_attention(q, k, v, causal=causal, q_offset=q_offset)
    if cfg.use_pallas and q.shape[1] == 1 and kv_valid_len is not None:
        from repro.kernels.decode_attention import ops as da
        return da.decode_attention(q, k, v, kv_valid_len)
    if cfg.attn_impl == "blocked" and kv_valid_len is None and q.shape[1] > 1:
        return blocked_attention(q, k, v, causal=causal, q_offset=q_offset,
                                 blk_q=cfg.attn_blk_q, blk_k=cfg.attn_blk_k,
                                 logits_soft_cap=logits_soft_cap)
    bias = None
    if kv_valid_len is not None:
        kpos = jnp.arange(k.shape[1])[None, :]
        keep = kpos < kv_valid_len[:, None]
        bias = jnp.where(keep, 0.0, -jnp.inf)[:, None, None, :]
    return sdpa(q, k, v, causal=causal, q_offset=q_offset, bias=bias,
                logits_soft_cap=logits_soft_cap)


def init_gqa(cfg: ModelConfig, key):
    """Standard (non-MLA) GQA projection params."""
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), cfg.jdtype),
        "wk": dense_init(ks[1], (d, hkv * hd), cfg.jdtype),
        "wv": dense_init(ks[2], (d, hkv * hd), cfg.jdtype),
        "wo": dense_init(ks[3], (h * hd, d), cfg.jdtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), cfg.jdtype)
        p["bk"] = jnp.zeros((hkv * hd,), cfg.jdtype)
        p["bv"] = jnp.zeros((hkv * hd,), cfg.jdtype)
    return p


def gqa_project_qkv(cfg: ModelConfig, p, x):
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(b, s, h, hd), k.reshape(b, s, hkv, hd), v.reshape(b, s, hkv, hd))


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu":
        return {"wi": dense_init(ks[0], (d, f), cfg.jdtype),
                "bi": jnp.zeros((f,), cfg.jdtype),
                "wo": dense_init(ks[1], (f, d), cfg.jdtype),
                "bo": jnp.zeros((d,), cfg.jdtype)}
    return {"wg": dense_init(ks[0], (d, f), cfg.jdtype),
            "wu": dense_init(ks[1], (d, f), cfg.jdtype),
            "wd": dense_init(ks[2], (f, d), cfg.jdtype)}


def apply_mlp(cfg: ModelConfig, p, x):
    if "wi" in p:
        return jax.nn.gelu((x @ p["wi"] + p["bi"]).astype(jnp.float32)).astype(x.dtype) @ p["wo"] + p["bo"]
    return (jax.nn.silu((x @ p["wg"]).astype(jnp.float32)).astype(x.dtype) * (x @ p["wu"])) @ p["wd"]


# ---------------------------------------------------------------------------
# embedding + chunked cross-entropy (never materializes [B,S,V] fp32 at once)
# ---------------------------------------------------------------------------
def init_embed(cfg: ModelConfig, key):
    p = {"tok": embed_init(key, (cfg.vocab_size, cfg.d_model), cfg.jdtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab_size), cfg.jdtype)
    return p


def embed_tokens(cfg: ModelConfig, p, tokens):
    from repro.parallel.sharding import with_logical_constraint
    out = jnp.take(p["tok"], tokens, axis=0)
    return with_logical_constraint(out, ("batch",) + (None,) * (out.ndim - 1))


def lm_head(cfg: ModelConfig, p, x):
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    return (x @ w) * cfg.logit_scale


def chunked_softmax_xent(cfg: ModelConfig, p, x, labels, mask=None):
    """Mean next-token cross-entropy, computed in seq-chunks of ``cfg.ce_chunk``.

    x [B,S,D] (pre-head hidden), labels [B,S] int32, mask [B,S] {0,1}.
    Avoids materializing the full [B,S,V] logits in fp32: each chunk's logits
    live only inside its scan step (XLA frees between steps; with remat the
    backward recomputes per chunk as well).
    """
    b, s, d = x.shape
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    chunk = min(cfg.ce_chunk, s)
    n = s // chunk
    rem = s - n * chunk
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    mask = mask.astype(jnp.float32)

    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def one(xc, yc, mc):
        # remat: per-chunk logits are recomputed in the backward pass, so no
        # [B, chunk, V] fp32 buffer is ever saved across chunks.
        from repro.parallel.sharding import with_logical_constraint
        xc = with_logical_constraint(xc, ("batch", None, None))
        logits = (xc @ w).astype(jnp.float32) * cfg.logit_scale   # [B,c,V]
        logits = with_logical_constraint(logits, ("batch", None, "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - tgt) * mc), jnp.sum(mc)

    def body(carry, args):
        tot, cnt = carry
        l, c = one(*args)
        return (tot + l, cnt + c), None

    xs = (x[:, : n * chunk].reshape(b, n, chunk, d).transpose(1, 0, 2, 3),
          labels[:, : n * chunk].reshape(b, n, chunk).transpose(1, 0, 2),
          mask[:, : n * chunk].reshape(b, n, chunk).transpose(1, 0, 2))
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), xs)
    if rem:
        l, c = one(x[:, n * chunk:], labels[:, n * chunk:], mask[:, n * chunk:])
        tot, cnt = tot + l, cnt + c
    return tot / jnp.maximum(cnt, 1.0)
