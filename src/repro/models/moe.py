"""Mixture-of-Experts transformer family.

Covers:
* deepseek-v2-lite-16b — MLA attention (kv_lora latent cache, decoupled rope),
  64 routed experts top-6 + 2 shared experts, leading dense layer(s);
* grok-1-314b         — GQA attention with tanh logit soft-cap, 8 experts top-2.

Expert dispatch is the dropped-token (E, C)-buffer pattern (GShard-style):
exact activated-FLOPs accounting, shardable experts axis (EP when divisible),
no [T, E, C] one-hot tensors.  ``moe_impl='ragged'`` switches to a dropless
sort + ``lax.ragged_dot`` path (perf-iteration alternative).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.base import ModelConfig, register_family


# ---------------------------------------------------------------------------
# router + expert FFN
# ---------------------------------------------------------------------------
def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(cfg.moe_capacity * n_tokens * cfg.moe_topk / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8


def router_probs(cfg: ModelConfig, p, x2d):
    logits = (x2d.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1)                      # [N, E] fp32


def moe_ffn(cfg: ModelConfig, p, x2d):
    """x2d [N, D] -> (y [N, D], aux_loss scalar). Dropped-token dispatch."""
    from repro.parallel.sharding import with_logical_constraint
    x2d = with_logical_constraint(x2d, ("batch", None))
    n, d = x2d.shape
    e, k = cfg.n_experts, cfg.moe_topk
    gates = router_probs(cfg, p, x2d)                           # [N, E]
    topv, topi = jax.lax.top_k(gates, k)                        # [N, K]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    inv_n = 1.0 / n
    p_mean = gates.mean(0)                                       # [E]
    f_e = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(inv_n / k)
    aux = cfg.router_aux_coef * e * jnp.sum(f_e * p_mean)

    if cfg.moe_impl == "ragged":
        y = _ragged_ffn(cfg, p, x2d, topi, topv)
        return y, aux

    if cfg.moe_impl == "ep":
        from repro.parallel.ep_dispatch import ep_moe_ffn
        from repro.parallel.sharding import _current_mesh
        mesh = _current_mesh()
        if mesh is not None and not mesh.empty and "model" in mesh.axis_names \
                and cfg.n_experts % mesh.shape["model"] == 0:
            y = ep_moe_ffn(x2d, p, mesh, topk=cfg.moe_topk,
                           capacity_factor=cfg.moe_capacity)
            return y, aux
        # no usable mesh: fall through to the SPMD grouped dispatch

    # ---- grouped (G, E, C) buffer dispatch (GShard-style) ----
    # Tokens are split into G groups aligned with the data axis; the
    # position-in-expert cumsum is per group, so dispatch is shard-local
    # (no cross-device prefix sums) and capacity buffers shard over data.
    g = max(1, min(cfg.moe_groups, n))
    while n % g:
        g //= 2
    ng = n // g                                                  # tokens/group
    c = _capacity(cfg, ng)
    e_flat = topi.reshape(g, ng * k)                             # [G, Nk]
    w_flat = topv.reshape(g, ng * k).astype(x2d.dtype)
    xg = x2d.reshape(g, ng, d)
    tok = jnp.arange(ng * k) // k
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)          # [G, Nk, E]
    pos = jnp.take_along_axis(jnp.cumsum(onehot, 1) - onehot,
                              e_flat[..., None], axis=2)[..., 0]  # [G, Nk]
    keep = pos < c

    # scatter/gather one top-k slot at a time, with the group axis as a vmap
    # BATCH dim — intermediates stay [G, ng, D], and the scatter/gather carry
    # no explicit G index, so XLA partitions them trivially along the data
    # axis (no cross-shard all-reduce; see EXPERIMENTS §Perf iters D1/D2).
    buf = jnp.zeros((g, e, c, d), x2d.dtype)
    scatter = jax.vmap(lambda b, ei, pi, xi: b.at[ei, pi].add(xi, mode="drop"))
    for j in range(k):
        e_j, pos_j, keep_j = e_flat[:, j::k], pos[:, j::k], keep[:, j::k]
        pos_j = jnp.where(keep_j, pos_j, c)                      # OOB -> drop
        buf = scatter(buf, e_j, pos_j, xg)
    buf = with_logical_constraint(buf, ("batch", "experts", None, None))

    # bf16 einsum outputs: the MXU accumulates f32 internally; keeping the
    # OUTPUT (and hence the bwd cotangents / gradient all-reduces) in bf16
    # halves the dominant collective volume (EXPERIMENTS §Perf G2).
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["wg"])
                    .astype(jnp.float32)).astype(x2d.dtype)
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["wu"])
    y_buf = jnp.einsum("gecf,efd->gecd", h, p["wd"])             # [G, E, C, D]
    y_buf = with_logical_constraint(y_buf, ("batch", "experts", None, None))

    gather = jax.vmap(lambda yb, ei, pi: yb[ei, pi])
    y = jnp.zeros((g, ng, d), x2d.dtype)
    for j in range(k):
        e_j, pos_j, keep_j = e_flat[:, j::k], pos[:, j::k], keep[:, j::k]
        got = gather(y_buf, e_j, jnp.minimum(pos_j, c - 1))      # [G, ng, D]
        y = y + jnp.where(keep_j[..., None], got, 0) * w_flat[:, j::k, None]
    return y.reshape(n, d), aux


def _ragged_ffn(cfg: ModelConfig, p, x2d, topi, topv):
    """Dropless dispatch: sort token-slots by expert, grouped matmul."""
    n, d = x2d.shape
    e, k = cfg.n_experts, cfg.moe_topk
    e_flat = topi.reshape(-1)
    order = jnp.argsort(e_flat)                                  # [NK]
    tok_sorted = (jnp.arange(n * k) // k)[order]
    xs = x2d[tok_sorted]                                         # [NK, D]
    group_sizes = jnp.bincount(e_flat, length=e).astype(jnp.int32)
    h = jax.nn.silu(jax.lax.ragged_dot(xs, p["wg"], group_sizes).astype(jnp.float32)).astype(x2d.dtype)
    h = h * jax.lax.ragged_dot(xs, p["wu"], group_sizes)
    ys = jax.lax.ragged_dot(h, p["wd"], group_sizes)
    w_sorted = topv.reshape(-1)[order].astype(x2d.dtype)
    out = jnp.zeros((n, d), x2d.dtype).at[tok_sorted].add(ys * w_sorted[:, None])
    return out


def init_moe_ffn(cfg: ModelConfig, key):
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], (d, e), jnp.float32),
        "wg": L.dense_init(ks[1], (e, d, f), cfg.jdtype, in_axis=1),
        "wu": L.dense_init(ks[2], (e, d, f), cfg.jdtype, in_axis=1),
        "wd": L.dense_init(ks[3], (e, f, d), cfg.jdtype, in_axis=1),
    }
    if cfg.n_shared_experts:
        fs = cfg.d_ff_expert * cfg.n_shared_experts
        p["shared"] = L.init_mlp(cfg, ks[4], d_ff=fs)
    return p


def apply_moe_block_ffn(cfg: ModelConfig, p, x):
    b, s, d = x.shape
    y, aux = moe_ffn(cfg, p, x.reshape(b * s, d))
    if "shared" in p:
        y = y + L.apply_mlp(cfg, p["shared"], x).reshape(b * s, d)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# MLA attention (deepseek-v2)
# ---------------------------------------------------------------------------
def init_mla(cfg: ModelConfig, key):
    d, h = cfg.d_model, cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": L.dense_init(ks[0], (d, h * qd), cfg.jdtype),
        "wdkv": L.dense_init(ks[1], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), cfg.jdtype),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), cfg.jdtype),
        "wuk": L.dense_init(ks[2], (cfg.kv_lora_rank, h * cfg.qk_nope_dim), cfg.jdtype),
        "wuv": L.dense_init(ks[3], (cfg.kv_lora_rank, h * cfg.v_head_dim), cfg.jdtype),
        "wo": L.dense_init(ks[4], (h * cfg.v_head_dim, d), cfg.jdtype),
    }


def mla_latents(cfg: ModelConfig, p, x, positions):
    """x [B,S,D] -> (c_kv [B,S,R], k_rope [B,S,1,rope]) with rope applied."""
    b, s, _ = x.shape
    dkv = x @ p["wdkv"]
    c_kv = L.rmsnorm(dkv[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = dkv[..., cfg.kv_lora_rank:].reshape(b, s, 1, cfg.qk_rope_dim)
    cos, sin = L.rope_freqs(cfg, positions, rot_dim=cfg.qk_rope_dim)
    k_rope = L.apply_rope(k_rope, cos, sin)
    return c_kv, k_rope


def mla_queries(cfg: ModelConfig, p, x, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    q = (x @ p["wq"]).reshape(b, s, h, qd)
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    cos, sin = L.rope_freqs(cfg, positions, rot_dim=cfg.qk_rope_dim)
    q_rope = L.apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def mla_attention_full(cfg: ModelConfig, p, x, positions, *, causal=True):
    """Training/prefill path: materialize per-head K,V from the latent."""
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = mla_queries(cfg, p, x, positions)
    c_kv, k_rope = mla_latents(cfg, p, x, positions)
    k_nope = (c_kv @ p["wuk"]).reshape(b, s, h, cfg.qk_nope_dim)
    v = (c_kv @ p["wuv"]).reshape(b, s, h, cfg.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, cfg.qk_rope_dim))], -1)
    attn = L.attention(cfg, q, k, v, causal=causal,
                       logits_soft_cap=cfg.logits_soft_cap)
    return attn.reshape(b, s, h * cfg.v_head_dim) @ p["wo"], (c_kv, k_rope)


def mla_attention_absorbed(cfg: ModelConfig, p, x, pos, c_kv_cache, k_rope_cache,
                           kv_valid_len):
    """Decode path: attend in the latent space (weight-absorbed, O(R) cache).

    x [B,1,D]; c_kv_cache [B,S,R]; k_rope_cache [B,S,rope].
    """
    b = x.shape[0]
    h, r = cfg.n_heads, cfg.kv_lora_rank
    q_nope, q_rope = mla_queries(cfg, p, x, pos[:, None])        # [B,1,H,*]
    # absorb W_uk into the query: score_nope = (q_nope W_uk^T) . c_kv
    wuk = p["wuk"].reshape(r, h, cfg.qk_nope_dim)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, wuk)            # [B,1,H,R]
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    s_nope = jnp.einsum("bqhr,bkr->bhqk", q_lat, c_kv_cache,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhe,bke->bhqk", q_rope, k_rope_cache,
                        preferred_element_type=jnp.float32)
    logits = (s_nope + s_rope) * scale
    if cfg.logits_soft_cap > 0:
        logits = cfg.logits_soft_cap * jnp.tanh(logits / cfg.logits_soft_cap)
    kpos = jnp.arange(c_kv_cache.shape[1])[None, :]
    keep = kpos < kv_valid_len[:, None]
    logits = jnp.where(keep[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhqk,bkr->bqhr", probs, c_kv_cache)      # [B,1,H,R]
    wuv = p["wuv"].reshape(r, h, cfg.v_head_dim)
    o = jnp.einsum("bqhr,rhv->bqhv", o_lat, wuv)                 # [B,1,H,V]
    return o.reshape(b, 1, h * cfg.v_head_dim) @ p["wo"]


# ---------------------------------------------------------------------------
# blocks / init
# ---------------------------------------------------------------------------
def _init_attn(cfg: ModelConfig, key):
    return init_mla(cfg, key) if cfg.use_mla else L.init_gqa(cfg, key)


def _init_moe_block(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)
    return {"ln1": L.init_norm(cfg, ks[0]), "attn": _init_attn(cfg, ks[1]),
            "ln2": L.init_norm(cfg, ks[2]), "moe": init_moe_ffn(cfg, ks[3])}


def _init_dense_block(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)
    return {"ln1": L.init_norm(cfg, ks[0]), "attn": _init_attn(cfg, ks[1]),
            "ln2": L.init_norm(cfg, ks[2]),
            "mlp": L.init_mlp(cfg, ks[3], d_ff=cfg.d_ff_dense or cfg.d_ff)}


def init(cfg: ModelConfig, key):
    k_emb, k_dense, k_layers, k_final = jax.random.split(key, 4)
    n_moe = cfg.n_layers - cfg.first_dense_layers
    stacked = jax.vmap(lambda k: _init_moe_block(cfg, k))(jax.random.split(k_layers, n_moe))
    p = {"embed": L.init_embed(cfg, k_emb), "layers": stacked,
         "final_norm": L.init_norm(cfg, k_final)}
    if cfg.first_dense_layers:
        p["dense_layers"] = [
            _init_dense_block(cfg, k)
            for k in jax.random.split(k_dense, cfg.first_dense_layers)]
    return p


def param_axes(cfg: ModelConfig):
    if cfg.use_mla:
        attn = {"wq": ("embed", "heads"), "wdkv": ("embed", None),
                "kv_norm": (None,), "wuk": (None, "heads"),
                "wuv": (None, "heads"), "wo": ("heads", "embed")}
    else:
        attn = {"wq": ("embed", "heads"), "wk": ("embed", "kv"),
                "wv": ("embed", "kv"), "wo": ("heads", "embed")}
        if cfg.qkv_bias:
            attn.update({"bq": ("heads",), "bk": ("kv",), "bv": ("kv",)})
    moe = {"router": ("embed", None),
           "wg": ("experts", "embed", "mlp"), "wu": ("experts", "embed", "mlp"),
           "wd": ("experts", "mlp", "embed")}
    if cfg.n_shared_experts:
        moe["shared"] = {"wg": ("embed", "mlp"), "wu": ("embed", "mlp"),
                         "wd": ("mlp", "embed")}
    norm = {"scale": (None,)}
    blk = {"ln1": dict(norm), "attn": attn, "ln2": dict(norm), "moe": moe}
    stack = jax.tree_util.tree_map(lambda ax: ("layers",) + ax, blk,
                                   is_leaf=lambda x: isinstance(x, tuple))
    emb = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        emb["head"] = ("embed", "vocab")
    out = {"embed": emb, "layers": stack, "final_norm": dict(norm)}
    if cfg.first_dense_layers:
        dblk = {"ln1": dict(norm), "attn": dict(attn), "ln2": dict(norm),
                "mlp": {"wg": ("embed", "mlp"), "wu": ("embed", "mlp"),
                        "wd": ("mlp", "embed")}}
        out["dense_layers"] = [dblk for _ in range(cfg.first_dense_layers)]
    return out


def inactive_expert_params(cfg: ModelConfig) -> int:
    """Params NOT activated per token (for 6*N_active*D accounting)."""
    per_expert = 3 * cfg.d_model * cfg.d_ff_expert
    n_moe_layers = cfg.n_layers - cfg.first_dense_layers
    return n_moe_layers * (cfg.n_experts - cfg.moe_topk) * per_expert


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _attn_full(cfg: ModelConfig, p, x, positions):
    if cfg.use_mla:
        out, _ = mla_attention_full(cfg, p, x, positions)
        return out
    b, s, _ = x.shape
    q, k, v = L.gqa_project_qkv(cfg, p, x)
    cos, sin = L.rope_freqs(cfg, positions)
    q, k = L.apply_rope(q, cos, sin), L.apply_rope(k, cos, sin)
    attn = L.attention(cfg, q, k, v, causal=True,
                       logits_soft_cap=cfg.logits_soft_cap)
    return attn.reshape(b, s, -1) @ p["wo"]


def _moe_block_fwd(cfg: ModelConfig, lp, x, positions):
    from repro.parallel.sharding import with_logical_constraint
    x = with_logical_constraint(x, ("batch", None, None))
    h = L.apply_norm(cfg, lp["ln1"], x)
    x = x + _attn_full(cfg, lp["attn"], h, positions)
    h = L.apply_norm(cfg, lp["ln2"], x)
    y, aux = apply_moe_block_ffn(cfg, lp["moe"], h)
    return x + y, aux


def _dense_block_fwd(cfg: ModelConfig, lp, x, positions):
    h = L.apply_norm(cfg, lp["ln1"], x)
    x = x + _attn_full(cfg, lp["attn"], h, positions)
    h = L.apply_norm(cfg, lp["ln2"], x)
    return x + L.apply_mlp(cfg, lp["mlp"], h)


def hidden_states(cfg: ModelConfig, params, tokens=None, inputs_embeds=None):
    x = inputs_embeds if inputs_embeds is not None else L.embed_tokens(cfg, params["embed"], tokens)
    positions = jnp.arange(x.shape[1])
    for lp in params.get("dense_layers", []):
        x = _dense_block_fwd(cfg, lp, x, positions)

    def body(carry, lp):
        x, aux = carry
        x, a = _moe_block_fwd(cfg, lp, x, positions)
        if cfg.seq_shard_carry:
            from repro.parallel.sharding import with_logical_constraint
            x = with_logical_constraint(x, ("batch", "act_seq", None))
        return (x, aux + a), None

    body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), params["layers"])
    return L.apply_norm(cfg, params["final_norm"], x), aux


def loss_fn(cfg: ModelConfig, params, batch, rng=None):
    x, aux = hidden_states(cfg, params, tokens=batch["tokens"])
    ce = L.chunked_softmax_xent(cfg, params["embed"], x, batch["labels"],
                                batch.get("mask"))
    return ce + aux, {"loss": ce, "aux_loss": aux}


def logits_fn(cfg: ModelConfig, params, tokens):
    x, _ = hidden_states(cfg, params, tokens=tokens)
    return L.lm_head(cfg, params["embed"], x)


# ---------------------------------------------------------------------------
# inference
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int, dtype=None):
    dtype = dtype or cfg.jdtype
    n_moe = cfg.n_layers - cfg.first_dense_layers
    if cfg.use_mla:
        cache = {
            "ckv": jnp.zeros((cfg.n_layers, batch_size, max_seq, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((cfg.n_layers, batch_size, max_seq, cfg.qk_rope_dim), dtype),
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }
    else:
        kv = (cfg.n_layers, batch_size, max_seq, cfg.kv_heads, cfg.head_dim)
        cache = {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
                 "pos": jnp.zeros((batch_size,), jnp.int32)}
    return cache


def cache_axes(cfg: ModelConfig):
    if cfg.use_mla:
        return {"ckv": ("layers", "batch", "kv_seq", None),
                "krope": ("layers", "batch", "kv_seq", None),
                "pos": ("batch",)}
    return {"k": ("layers", "batch", "kv_seq", "kv", None),
            "v": ("layers", "batch", "kv_seq", "kv", None),
            "pos": ("batch",)}


def _split_cache(cache, n_dense):
    """Split stacked cache arrays into (dense prefix list, moe stacked)."""
    dense = [jax.tree_util.tree_map(lambda a: a[i], {k: v for k, v in cache.items() if k != "pos"})
             for i in range(n_dense)]
    moe = {k: v[n_dense:] for k, v in cache.items() if k != "pos"}
    return dense, moe


def prefill(cfg: ModelConfig, params, tokens, cache):
    b, s = tokens.shape
    x = L.embed_tokens(cfg, params["embed"], tokens)
    positions = jnp.arange(s)
    new_layers = []
    for lp in params.get("dense_layers", []):
        h = L.apply_norm(cfg, lp["ln1"], x)
        if cfg.use_mla:
            out, (ckv, krope) = mla_attention_full(cfg, lp["attn"], h, positions)
            new_layers.append({"ckv": ckv, "krope": krope[:, :, 0]})
            x = x + out
        else:
            q, k, v = L.gqa_project_qkv(cfg, lp["attn"], h)
            cos, sin = L.rope_freqs(cfg, positions)
            q, k = L.apply_rope(q, cos, sin), L.apply_rope(k, cos, sin)
            attn = L.attention(cfg, q, k, v, causal=True,
                               logits_soft_cap=cfg.logits_soft_cap)
            new_layers.append({"k": k, "v": v})
            x = x + attn.reshape(b, s, -1) @ lp["attn"]["wo"]
        h = L.apply_norm(cfg, lp["ln2"], x)
        x = x + L.apply_mlp(cfg, lp["mlp"], h)

    def body(carry, lp):
        x = carry
        h = L.apply_norm(cfg, lp["ln1"], x)
        if cfg.use_mla:
            out, (ckv, krope) = mla_attention_full(cfg, lp["attn"], h, positions)
            kv = {"ckv": ckv, "krope": krope[:, :, 0]}
            x = x + out
        else:
            q, k, v = L.gqa_project_qkv(cfg, lp["attn"], h)
            cos, sin = L.rope_freqs(cfg, positions)
            q, k = L.apply_rope(q, cos, sin), L.apply_rope(k, cos, sin)
            attn = L.attention(cfg, q, k, v, causal=True,
                               logits_soft_cap=cfg.logits_soft_cap)
            kv = {"k": k, "v": v}
            x = x + attn.reshape(b, s, -1) @ lp["attn"]["wo"]
        h = L.apply_norm(cfg, lp["ln2"], x)
        y, _ = apply_moe_block_ffn(cfg, lp["moe"], h)
        return x + y, kv

    body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat else body
    x, kvs = jax.lax.scan(body_fn, x, params["layers"])

    cache = dict(cache)
    for name in [k for k in cache if k != "pos"]:
        stacked = kvs[name]
        if new_layers:
            head = jnp.stack([nl[name] for nl in new_layers])
            stacked = jnp.concatenate([head, stacked], 0)
        pad = [(0, 0)] * stacked.ndim
        pad[2] = (0, cache[name].shape[2] - s)
        cache[name] = jax.lax.dynamic_update_slice(
            cache[name], stacked.astype(cache[name].dtype),
            (0,) * cache[name].ndim)
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return L.lm_head(cfg, params["embed"], x[:, -1:]), cache


def _decode_attn(cfg, lp, x, pos, lc, valid):
    """One-token attention against this layer's cache slice; returns (out, new lc)."""
    b = x.shape[0]
    if cfg.use_mla:
        dkv = x @ lp["attn"]["wdkv"]
        ckv_new = L.rmsnorm(dkv[..., : cfg.kv_lora_rank], lp["attn"]["kv_norm"])
        kr = dkv[..., cfg.kv_lora_rank:].reshape(b, 1, 1, cfg.qk_rope_dim)
        cos, sin = L.rope_freqs(cfg, pos[:, None], rot_dim=cfg.qk_rope_dim)
        kr = L.apply_rope(kr, cos, sin)[:, 0, 0]
        ckv = lc["ckv"].at[jnp.arange(b), pos].set(ckv_new[:, 0].astype(lc["ckv"].dtype))
        krope = lc["krope"].at[jnp.arange(b), pos].set(kr.astype(lc["krope"].dtype))
        out = mla_attention_absorbed(cfg, lp["attn"], x, pos, ckv, krope, valid)
        return out, {"ckv": ckv, "krope": krope}
    q, k, v = L.gqa_project_qkv(cfg, lp["attn"], x)
    cos, sin = L.rope_freqs(cfg, pos[:, None])
    q, k = L.apply_rope(q, cos, sin), L.apply_rope(k, cos, sin)
    ck = lc["k"].at[jnp.arange(b), pos].set(k[:, 0].astype(lc["k"].dtype))
    cv = lc["v"].at[jnp.arange(b), pos].set(v[:, 0].astype(lc["v"].dtype))
    attn = L.attention(cfg, q, ck, cv, causal=False, kv_valid_len=valid,
                       logits_soft_cap=cfg.logits_soft_cap)
    return attn.reshape(b, 1, -1) @ lp["attn"]["wo"], {"k": ck, "v": cv}


def decode_step(cfg: ModelConfig, params, cache, tokens):
    b = tokens.shape[0]
    pos = cache["pos"]
    valid = pos + 1
    x = L.embed_tokens(cfg, params["embed"], tokens)
    n_dense = cfg.first_dense_layers
    kv_names = [k for k in cache if k != "pos"]
    new_dense = []
    for i, lp in enumerate(params.get("dense_layers", [])):
        lc = {name: cache[name][i] for name in kv_names}
        h = L.apply_norm(cfg, lp["ln1"], x)
        out, nlc = _decode_attn(cfg, lp, h, pos, lc, valid)
        x = x + out
        h = L.apply_norm(cfg, lp["ln2"], x)
        x = x + L.apply_mlp(cfg, lp["mlp"], h)
        new_dense.append(nlc)

    def body(carry, xs):
        x = carry
        lp = xs[0]
        lc = {name: xs[1 + j] for j, name in enumerate(kv_names)}
        h = L.apply_norm(cfg, lp["ln1"], x)
        out, nlc = _decode_attn(cfg, lp, h, pos, lc, valid)
        x = x + out
        h = L.apply_norm(cfg, lp["ln2"], x)
        y, _ = apply_moe_block_ffn(cfg, lp["moe"], h)
        return x + y, tuple(nlc[name] for name in kv_names)

    moe_cache = tuple(cache[name][n_dense:] for name in kv_names)
    x, new_moe = jax.lax.scan(body, x, (params["layers"],) + moe_cache)

    cache = dict(cache)
    for j, name in enumerate(kv_names):
        stacked = new_moe[j]
        if new_dense:
            head = jnp.stack([nd[name] for nd in new_dense])
            stacked = jnp.concatenate([head, stacked], 0)
        cache[name] = stacked
    cache["pos"] = pos + 1
    x = L.apply_norm(cfg, params["final_norm"], x)
    return L.lm_head(cfg, params["embed"], x), cache


register_family("moe")(__import__("sys").modules[__name__])
