"""Zamba2 hybrid (arXiv:2411.15242): Mamba-2 backbone + shared attention block.

* ``cfg.n_layers`` Mamba-2 (SSD) blocks at width D;
* one **shared** transformer block (attention + MLP) at width 2D, applied after
  every ``cfg.shared_attn_every`` Mamba blocks on ``concat(hidden, embed0)``
  with per-application LoRA deltas on the QKV projections, projected back to D;
* decode state: per-block conv + SSD states (O(1) in context) plus one KV cache
  per shared-block application (the only context-length-dependent memory).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan import ops as ssd_ops
from repro.models import layers as L
from repro.models.base import ModelConfig, register_family

LORA_RANK = 64


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    h_ssm = d_inner // cfg.ssm_head_dim
    d_conv = d_inner + 2 * cfg.ssm_state          # conv covers x, B, C
    return d_inner, h_ssm, d_conv


def _n_apps(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_mamba_block(cfg: ModelConfig, key):
    d = cfg.d_model
    d_inner, h_ssm, d_conv = _dims(cfg)
    n = cfg.ssm_state
    ks = jax.random.split(key, 4)
    dt = cfg.jdtype
    return {
        "norm": {"scale": jnp.ones((d,), dt)},
        "in_proj": L.dense_init(ks[0], (d, 2 * d_inner + 2 * n + h_ssm), dt),
        "conv_w": L.dense_init(ks[1], (cfg.ssm_conv_width, d_conv), dt),
        "conv_b": jnp.zeros((d_conv,), dt),
        "dt_bias": jnp.zeros((h_ssm,), dt),
        "A_log": jnp.zeros((h_ssm,), jnp.float32),       # A = -exp(A_log)
        "D": jnp.ones((h_ssm,), jnp.float32),
        "gate_norm": {"scale": jnp.ones((d_inner,), dt)},
        "out_proj": L.dense_init(ks[2], (d_inner, d), dt),
    }


def _init_shared_block(cfg: ModelConfig, key):
    d2 = 2 * cfg.d_model
    h, hd = cfg.n_heads, cfg.head_dim                     # at width 2D
    ks = jax.random.split(key, 9)
    dt = cfg.jdtype
    napps = _n_apps(cfg)
    return {
        "ln1": {"scale": jnp.ones((d2,), dt)},
        "wq": L.dense_init(ks[0], (d2, h * hd), dt),
        "wk": L.dense_init(ks[1], (d2, cfg.kv_heads * hd), dt),
        "wv": L.dense_init(ks[2], (d2, cfg.kv_heads * hd), dt),
        "wo": L.dense_init(ks[3], (h * hd, d2), dt),
        "lora_a": (jax.random.normal(ks[4], (napps, 3, d2, LORA_RANK), jnp.float32) * 0.02).astype(dt),
        "lora_b": jnp.zeros((napps, 3, LORA_RANK, h * hd), dt),
        "ln2": {"scale": jnp.ones((d2,), dt)},
        "mlp": {"wg": L.dense_init(ks[5], (d2, cfg.d_ff), dt),
                "wu": L.dense_init(ks[6], (d2, cfg.d_ff), dt),
                "wd": L.dense_init(ks[7], (cfg.d_ff, d2), dt)},
        "out": L.dense_init(ks[8], (d2, cfg.d_model), dt),
    }


def init(cfg: ModelConfig, key):
    k_emb, k_m, k_s, k_f = jax.random.split(key, 4)
    stacked = jax.vmap(lambda k: _init_mamba_block(cfg, k))(
        jax.random.split(k_m, cfg.n_layers))
    return {
        "embed": L.init_embed(cfg, k_emb),
        "mamba": stacked,
        "shared": _init_shared_block(cfg, k_s),
        "final_norm": {"scale": jnp.ones((cfg.d_model,), cfg.jdtype)},
    }


def param_axes(cfg: ModelConfig):
    mb = {"norm": {"scale": (None,)},
          "in_proj": ("embed", "mlp"), "conv_w": (None, "mlp"), "conv_b": ("mlp",),
          "dt_bias": (None,), "A_log": (None,), "D": (None,),
          "gate_norm": {"scale": ("mlp",)}, "out_proj": ("mlp", "embed")}
    mb = jax.tree_util.tree_map(lambda ax: ("layers",) + ax, mb,
                                is_leaf=lambda x: isinstance(x, tuple))
    sh = {"ln1": {"scale": (None,)},
          "wq": ("embed", "heads"), "wk": ("embed", "kv"), "wv": ("embed", "kv"),
          "wo": ("heads", "embed"),
          "lora_a": (None, None, "embed", None), "lora_b": (None, None, None, "heads"),
          "ln2": {"scale": (None,)},
          "mlp": {"wg": ("embed", "mlp"), "wu": ("embed", "mlp"), "wd": ("mlp", "embed")},
          "out": ("embed", None)}
    emb = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        emb["head"] = ("embed", "vocab")
    return {"embed": emb, "mamba": mb, "shared": sh,
            "final_norm": {"scale": (None,)}}


# ---------------------------------------------------------------------------
# mamba block forward
# ---------------------------------------------------------------------------
def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv, width W. x [B,S,C]; w [W,C]; conv_state [B,W-1,C].

    Returns (y [B,S,C], new_conv_state [B,W-1,C]).
    """
    width = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)          # [B, S+W-1, C]
    y = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(width)) + b
    new_state = xp[:, -(width - 1):]
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), new_state


def _mamba_block(cfg: ModelConfig, p, x, state):
    """x [B,S,D]; state {conv [B,W-1,Cc], ssd [B,H,P,N]}."""
    from repro.parallel.sharding import with_logical_constraint
    x = with_logical_constraint(x, ("batch", None, None))
    b, s, d = x.shape
    d_inner, h_ssm, d_conv = _dims(cfg)
    n = cfg.ssm_state
    hres = x
    x = L.rmsnorm(x, p["norm"]["scale"])
    proj = x @ p["in_proj"]
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner: d_inner + d_conv]
    dt_raw = proj[..., d_inner + d_conv:]
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], state["conv"])
    xs = xbc[..., :d_inner].reshape(b, s, h_ssm, cfg.ssm_head_dim)
    Bm = xbc[..., d_inner: d_inner + n]
    Cm = xbc[..., d_inner + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"])
    y, new_ssd = ssd_ops.ssd(xs, dt, A, Bm, Cm, p["D"], state["ssd"],
                             use_pallas=cfg.use_pallas)
    y = y.reshape(b, s, d_inner)
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                  p["gate_norm"]["scale"])
    return hres + y @ p["out_proj"], {"conv": new_conv, "ssd": new_ssd}


def init_mamba_states(cfg: ModelConfig, batch_size: int):
    d_inner, h_ssm, d_conv = _dims(cfg)
    return {
        "conv": jnp.zeros((cfg.n_layers, batch_size, cfg.ssm_conv_width - 1, d_conv), cfg.jdtype),
        "ssd": jnp.zeros((cfg.n_layers, batch_size, h_ssm, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# shared attention block (width 2D), per-application LoRA
# ---------------------------------------------------------------------------
def _shared_qkv(cfg, p, h2, app_idx):
    b, s, _ = h2.shape
    hn, hkv, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    la, lb = p["lora_a"][app_idx], p["lora_b"][app_idx]    # [3,2D,r],[3,r,H*hd]
    q = h2 @ p["wq"] + (h2 @ la[0]) @ lb[0]
    k = h2 @ p["wk"] + ((h2 @ la[1]) @ lb[1])[..., : hkv * hd]
    v = h2 @ p["wv"] + ((h2 @ la[2]) @ lb[2])[..., : hkv * hd]
    return (q.reshape(b, s, hn, hd), k.reshape(b, s, hkv, hd),
            v.reshape(b, s, hkv, hd))


def _shared_block(cfg: ModelConfig, p, h, emb0, app_idx, *, positions,
                  cache_kv=None, pos=None, kv_valid_len=None):
    """h [B,S,D] + emb0 [B,S,D] -> delta [B,S,D]; optional KV-cache decode."""
    b, s, _ = h.shape
    x2 = jnp.concatenate([h, emb0], axis=-1)               # [B,S,2D]
    y = L.rmsnorm(x2, p["ln1"]["scale"])
    q, k, v = _shared_qkv(cfg, p, y, app_idx)
    cos, sin = L.rope_freqs(cfg, positions)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    if cache_kv is not None:
        ck, cv = cache_kv
        ck = ck.at[jnp.arange(b), pos].set(k[:, 0].astype(ck.dtype))
        cv = cv.at[jnp.arange(b), pos].set(v[:, 0].astype(cv.dtype))
        new_kv = (ck, cv)
        attn = L.attention(cfg, q, ck, cv, causal=False, kv_valid_len=kv_valid_len)
    else:
        new_kv = (k, v)          # full-seq KV (prefill collects these)
        attn = L.attention(cfg, q, k, v, causal=True)
    x2 = x2 + attn.reshape(b, s, -1) @ p["wo"]
    y = L.rmsnorm(x2, p["ln2"]["scale"])
    x2 = x2 + L.apply_mlp(cfg, p["mlp"], y)
    return x2 @ p["out"], new_kv


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------
def _slice_layers(tree, lo, hi):
    return jax.tree_util.tree_map(lambda a: a[lo:hi], tree)


def _segments(cfg: ModelConfig):
    """[(start, end, apply_shared_after)] covering all mamba blocks."""
    segs = []
    step = cfg.shared_attn_every
    i = 0
    app = 0
    while i < cfg.n_layers:
        j = min(i + step, cfg.n_layers)
        has_app = (j - i == step) and (app < _n_apps(cfg))
        segs.append((i, j, app if has_app else None))
        if has_app:
            app += 1
        i = j
    return segs


def _run(cfg: ModelConfig, params, x, emb0, states, *, positions,
         shared_caches=None, pos=None, kv_valid_len=None):
    """states: stacked mamba states; shared_caches: {k,v} [n_apps,...] or None."""
    def seg_scan(x, seg_params, seg_states):
        def body(carry, xs):
            lp, st = xs
            y, new_st = _mamba_block(cfg, lp, carry, st)
            if cfg.seq_shard_carry and y.shape[1] > 1:
                from repro.parallel.sharding import with_logical_constraint
                y = with_logical_constraint(y, ("batch", "act_seq", None))
            return y, new_st
        if cfg.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        return jax.lax.scan(body, x, (seg_params, seg_states))

    new_states = []
    new_shared = []
    for (lo, hi, app) in _segments(cfg):
        x, new_st = seg_scan(x, _slice_layers(params["mamba"], lo, hi),
                             _slice_layers(states, lo, hi))
        new_states.append(new_st)
        if app is not None:
            if shared_caches is not None:
                ckv = (shared_caches["k"][app], shared_caches["v"][app])
                delta, new_kv = _shared_block(
                    cfg, params["shared"], x, emb0, app, positions=positions,
                    cache_kv=ckv, pos=pos, kv_valid_len=kv_valid_len)
                new_shared.append(new_kv)
            else:
                delta, kvs = _shared_block(cfg, params["shared"], x, emb0, app,
                                           positions=positions)
                new_shared.append(kvs)
            x = x + delta
    states_out = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, 0), *new_states)
    return x, states_out, new_shared


def hidden_states(cfg: ModelConfig, params, tokens, states=None):
    b, s = tokens.shape
    emb0 = L.embed_tokens(cfg, params["embed"], tokens)
    x = emb0
    states = states if states is not None else init_mamba_states(cfg, b)
    x, new_states, _ = _run(cfg, params, x, emb0, states,
                            positions=jnp.arange(s))
    return L.rmsnorm(x, params["final_norm"]["scale"]), new_states


def loss_fn(cfg: ModelConfig, params, batch, rng=None):
    x, _ = hidden_states(cfg, params, batch["tokens"])
    loss = L.chunked_softmax_xent(cfg, params["embed"], x, batch["labels"],
                                  batch.get("mask"))
    return loss, {"loss": loss}


def logits_fn(cfg: ModelConfig, params, tokens):
    x, _ = hidden_states(cfg, params, tokens)
    return L.lm_head(cfg, params["embed"], x)


# ---------------------------------------------------------------------------
# inference
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int, dtype=None):
    dtype = dtype or cfg.jdtype
    napps = _n_apps(cfg)
    kv = (napps, batch_size, max_seq, cfg.kv_heads, cfg.head_dim)
    cache = init_mamba_states(cfg, batch_size)
    cache.update({"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
                  "pos": jnp.zeros((batch_size,), jnp.int32)})
    return cache


def cache_axes(cfg: ModelConfig):
    return {"conv": ("layers", "batch", None, "mlp"),
            "ssd": ("layers", "batch", "heads", None, None),
            "k": (None, "batch", "kv_seq", "kv", None),
            "v": (None, "batch", "kv_seq", "kv", None),
            "pos": ("batch",)}


def prefill(cfg: ModelConfig, params, tokens, cache):
    b, s = tokens.shape
    emb0 = L.embed_tokens(cfg, params["embed"], tokens)
    states = {k: cache[k] for k in ("conv", "ssd")}
    x, new_states, shared_kvs = _run(cfg, params, emb0, emb0, states,
                                     positions=jnp.arange(s))
    new_cache = dict(new_states)
    max_seq = cache["k"].shape[2]
    ks = jnp.stack([kv[0] for kv in shared_kvs])           # [n_apps,B,S,Hkv,hd]
    vs = jnp.stack([kv[1] for kv in shared_kvs])
    new_cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    new_cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    new_cache["pos"] = jnp.full((b,), s, jnp.int32)
    x = L.rmsnorm(x, params["final_norm"]["scale"])
    return L.lm_head(cfg, params["embed"], x[:, -1:]), new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens):
    b = tokens.shape[0]
    pos = cache["pos"]
    emb0 = L.embed_tokens(cfg, params["embed"], tokens)
    states = {k: cache[k] for k in ("conv", "ssd")}
    shared = {"k": cache["k"], "v": cache["v"]}
    x, new_states, new_kvs = _run(cfg, params, emb0, emb0, states,
                                  positions=pos[:, None], shared_caches=shared,
                                  pos=pos, kv_valid_len=pos + 1)
    new_cache = dict(new_states)
    new_cache["k"] = jnp.stack([kv[0] for kv in new_kvs])
    new_cache["v"] = jnp.stack([kv[1] for kv in new_kvs])
    new_cache["pos"] = pos + 1
    x = L.rmsnorm(x, params["final_norm"]["scale"])
    return L.lm_head(cfg, params["embed"], x), new_cache


register_family("zamba2")(__import__("sys").modules[__name__])
