"""Dense decoder-only transformer family.

Covers smollm-135m, qwen2-0.5b, minicpm-2b, stablelm-3b and the internlm2
backbone of internvl2-2b via ModelConfig flags (norm type, partial rotary,
qkv bias, residual/logit scaling, GQA widths).

Layers are stacked on a leading axis and executed with ``lax.scan`` so compile
time is depth-independent; each block is rematerialized when ``cfg.remat``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.base import ModelConfig, register_family


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_block(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)
    return {
        "ln1": L.init_norm(cfg, ks[0]),
        "attn": L.init_gqa(cfg, ks[1]),
        "ln2": L.init_norm(cfg, ks[2]),
        "mlp": L.init_mlp(cfg, ks[3]),
    }


def init(cfg: ModelConfig, key):
    k_emb, k_layers, k_final = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _init_block(cfg, k))(layer_keys)
    return {
        "embed": L.init_embed(cfg, k_emb),
        "layers": stacked,
        "final_norm": L.init_norm(cfg, k_final),
    }


def param_axes(cfg: ModelConfig):
    """Logical-axis names, same tree structure as init()."""
    def blk():
        attn = {"wq": ("embed", "heads"), "wk": ("embed", "kv"),
                "wv": ("embed", "kv"), "wo": ("heads", "embed")}
        if cfg.qkv_bias:
            attn.update({"bq": ("heads",), "bk": ("kv",), "bv": ("kv",)})
        mlp = ({"wi": ("embed", "mlp"), "bi": ("mlp",),
                "wo": ("mlp", "embed"), "bo": ("embed",)}
               if cfg.act == "gelu" else
               {"wg": ("embed", "mlp"), "wu": ("embed", "mlp"), "wd": ("mlp", "embed")})
        norm = ({"scale": (None,), "bias": (None,)} if cfg.norm == "layernorm"
                else {"scale": (None,)})
        return {"ln1": dict(norm), "attn": attn, "ln2": dict(norm), "mlp": mlp}

    def stack(tree):
        return jax.tree_util.tree_map(lambda ax: ("layers",) + ax, tree,
                                      is_leaf=lambda x: isinstance(x, tuple))

    emb = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        emb["head"] = ("embed", "vocab")
    norm = ({"scale": (None,), "bias": (None,)} if cfg.norm == "layernorm"
            else {"scale": (None,)})
    return {"embed": emb, "layers": stack(blk()), "final_norm": dict(norm)}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _block(cfg: ModelConfig, p, x, cos, sin, *, causal=True, q_offset=0,
           cache_kv=None, pos=None, kv_valid_len=None):
    """One transformer block. Returns (x, new_cache_kv or None)."""
    from repro.parallel.sharding import with_logical_constraint
    x = with_logical_constraint(x, ("batch", None, None))
    h = L.apply_norm(cfg, p["ln1"], x)
    q, k, v = L.gqa_project_qkv(cfg, p["attn"], h)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    new_kv = None
    if cache_kv is not None:
        ck, cv = cache_kv
        b = x.shape[0]
        ck = ck.at[jnp.arange(b), pos].set(k[:, 0].astype(ck.dtype))
        cv = cv.at[jnp.arange(b), pos].set(v[:, 0].astype(cv.dtype))
        k, v, new_kv = ck, cv, (ck, cv)
        attn_out = L.attention(cfg, q, k, v, causal=False, kv_valid_len=kv_valid_len)
    else:
        attn_out = L.attention(cfg, q, k, v, causal=causal, q_offset=q_offset)
    b, s = x.shape[:2]
    x = x + (attn_out.reshape(b, s, -1) @ p["attn"]["wo"]) * cfg.residual_scale
    h = L.apply_norm(cfg, p["ln2"], x)
    x = x + L.apply_mlp(cfg, p["mlp"], h) * cfg.residual_scale
    return x, new_kv


def _run_stack(cfg: ModelConfig, params, x, cos, sin, *, q_offset=0):
    """scan over stacked layers (training / prefill: no cache)."""
    def body(carry, lp):
        y, _ = _block(cfg, lp, carry, cos, sin, causal=True, q_offset=q_offset)
        if cfg.seq_shard_carry:
            from repro.parallel.sharding import with_logical_constraint
            y = with_logical_constraint(y, ("batch", "act_seq", None))
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.use_scan:
        x, _ = jax.lax.scan(body, x, params["layers"])
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, _ = body(x, lp)
    return x


def hidden_states(cfg: ModelConfig, params, tokens=None, inputs_embeds=None, positions=None):
    """Full-sequence forward to final hidden states [B,S,D]."""
    x = inputs_embeds if inputs_embeds is not None else L.embed_tokens(cfg, params["embed"], tokens)
    s = x.shape[1]
    if positions is None:
        positions = jnp.arange(s)
    cos, sin = L.rope_freqs(cfg, positions)
    x = _run_stack(cfg, params, x, cos, sin)
    return L.apply_norm(cfg, params["final_norm"], x)


def loss_fn(cfg: ModelConfig, params, batch, rng=None):
    x = hidden_states(cfg, params, tokens=batch["tokens"])
    loss = L.chunked_softmax_xent(cfg, params["embed"], x, batch["labels"],
                                  batch.get("mask"))
    return loss, {"loss": loss}


def logits_fn(cfg: ModelConfig, params, tokens):
    x = hidden_states(cfg, params, tokens=tokens)
    return L.lm_head(cfg, params["embed"], x)


# ---------------------------------------------------------------------------
# inference: prefill + single-token decode with pre-allocated KV cache
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int, dtype=None):
    dtype = dtype or cfg.jdtype
    kv_shape = (cfg.n_layers, batch_size, max_seq, cfg.kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(kv_shape, dtype),
        "v": jnp.zeros(kv_shape, dtype),
        "pos": jnp.zeros((batch_size,), jnp.int32),
    }


def cache_axes(cfg: ModelConfig):
    return {"k": ("layers", "batch", "kv_seq", "kv", None),
            "v": ("layers", "batch", "kv_seq", "kv", None),
            "pos": ("batch",)}


def _prefill_stack(cfg: ModelConfig, params, tokens):
    """Shared prompt pass: tokens [B,S] -> (final-normed hidden [B,S,D],
    per-layer ks, vs [L,B,S,Hkv,D]).  Backs both the batched ``prefill`` and
    the unbatched ``prefill_fn`` so the block arithmetic exists once."""
    b, s = tokens.shape
    x = L.embed_tokens(cfg, params["embed"], tokens)
    cos, sin = L.rope_freqs(cfg, jnp.arange(s))

    def body(carry, lp):
        y = carry
        h = L.apply_norm(cfg, lp["ln1"], y)
        q, k, v = L.gqa_project_qkv(cfg, lp["attn"], h)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        attn_out = L.attention(cfg, q, k, v, causal=True)
        y = y + (attn_out.reshape(b, s, -1) @ lp["attn"]["wo"]) * cfg.residual_scale
        h = L.apply_norm(cfg, lp["ln2"], y)
        y = y + L.apply_mlp(cfg, lp["mlp"], h) * cfg.residual_scale
        return y, (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    return L.apply_norm(cfg, params["final_norm"], x), ks, vs


def prefill(cfg: ModelConfig, params, tokens, cache):
    """Run the prompt, fill the cache, return last-position logits."""
    b, s = tokens.shape
    x, ks, vs = _prefill_stack(cfg, params, tokens)
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    return L.lm_head(cfg, params["embed"], x[:, -1:]), cache


def decode_step(cfg: ModelConfig, params, cache, tokens):
    """tokens [B,1] -> (logits [B,1,V], cache). Positions come from cache."""
    b = tokens.shape[0]
    pos = cache["pos"]                      # [B]
    x = L.embed_tokens(cfg, params["embed"], tokens)
    cos, sin = L.rope_freqs(cfg, pos[:, None])
    valid = pos + 1

    def body(carry, xs):
        y = carry
        lp, ck, cv = xs
        y, new_kv = _block(cfg, lp, y, cos, sin, cache_kv=(ck, cv), pos=pos,
                           kv_valid_len=valid)
        return y, new_kv

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    cache = dict(cache)
    cache["k"], cache["v"] = ks, vs
    cache["pos"] = pos + 1
    x = L.apply_norm(cfg, params["final_norm"], x)
    return L.lm_head(cfg, params["embed"], x), cache


# ---------------------------------------------------------------------------
# incremental single-sequence decode (unbatched; base.seq_prefill/seq_step)
# ---------------------------------------------------------------------------
def prefill_fn(cfg: ModelConfig, params, toks, plen):
    """toks [S] i32 padded buffer, plen scalar true length ->
    (logits [V] f32 at position plen-1, cache {k, v: [L, S, Hkv, D]}).

    Runs the whole buffer once (causal), so cache rows at positions >= plen
    hold K/V of padding tokens — masked by ``step_fn``'s valid length and
    overwritten as the sequence grows, never observed.
    """
    x, ks, vs = _prefill_stack(cfg, params, toks[None])
    h_last = jax.lax.dynamic_index_in_dim(
        x[0], jnp.asarray(plen, jnp.int32) - 1, axis=0, keepdims=False)
    logits = L.lm_head(cfg, params["embed"], h_last[None, None])[0, 0]
    return logits.astype(jnp.float32), {"k": ks[:, 0], "v": vs[:, 0]}


def step_fn(cfg: ModelConfig, params, cache, tok, pos):
    """One incremental token: cache {k, v: [L, S, Hkv, D]}, tok/pos scalars
    -> (logits [V] f32 for position pos+1, cache).  Attention reads the
    per-layer cache row through ``kernels/decode_attention`` (Pallas on TPU
    when ``cfg.use_pallas``, the jnp flash-decode oracle elsewhere).

    Kept as its own scan body rather than reusing ``_block``: the cache here
    is unbatched (rows are vmapped by the search strategies), and the
    attention is pinned to the decode kernel's flash path instead of
    ``L.attention``'s sdpa-with-bias dispatch.
    """
    from repro.kernels.decode_attention import ops as da

    pos = jnp.asarray(pos, jnp.int32)
    x = L.embed_tokens(cfg, params["embed"],
                       jnp.asarray(tok, jnp.int32).reshape(1, 1))
    cos, sin = L.rope_freqs(cfg, pos.reshape(1, 1))
    valid = (pos + 1).reshape(1)

    def body(carry, xs):
        y = carry
        lp, ck, cv = xs                              # ck/cv [S, Hkv, D]
        h = L.apply_norm(cfg, lp["ln1"], y)
        q, k, v = L.gqa_project_qkv(cfg, lp["attn"], h)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
        ck = jax.lax.dynamic_update_slice(ck, k[0].astype(ck.dtype), (pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v[0].astype(cv.dtype), (pos, 0, 0))
        attn_out = da.decode_attention(q, ck[None], cv[None], valid,
                                       use_ref=not cfg.use_pallas)
        y = y + (attn_out.reshape(1, 1, -1) @ lp["attn"]["wo"]) * cfg.residual_scale
        h = L.apply_norm(cfg, lp["ln2"], y)
        y = y + L.apply_mlp(cfg, lp["mlp"], h) * cfg.residual_scale
        return y, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_head(cfg, params["embed"], x)[0, 0]
    return logits.astype(jnp.float32), {"k": ks, "v": vs}


register_family("dense")(__import__("sys").modules[__name__])
