"""Model-zoo base: config dataclass, family registry, abstract-shape helpers.

Every architecture in the assigned pool is an instance of ``ModelConfig``
handled by one of the family modules (dense / moe / whisper / rwkv6 / zamba2 /
vlm).  The family module implements the functional model API:

    init(cfg, rng)                      -> params pytree
    loss_fn(cfg, params, batch, rng)    -> (loss, aux)          # training fwd
    prefill(cfg, params, batch)         -> (logits_last, cache) # inference
    decode_step(cfg, params, cache, tokens, pos) -> (logits, cache)
    init_cache(cfg, batch, seq)         -> cache pytree (abstract-safe)
    param_axes(cfg)                     -> logical-axis pytree (same structure
                                           as params; tuples of axis names)

Incremental single-sequence decode (optional; KV-cache-aware MCTS decode):

    prefill_fn(cfg, params, toks, plen) -> (logits, cache)
    step_fn(cfg, params, cache, tok, pos) -> (logits, cache)

Unlike ``prefill``/``decode_step`` these are *unbatched* (no leading batch
axis; ``tok``/``pos`` are scalars, ``logits`` is ``[V]`` fp32) so search
strategies can thread the cache through vmapped/scanned tree state
(``core.domains.lm_decode.CachedLMDecodeDomain``).  ``prefill_fn`` runs the
whole padded buffer ``toks`` once and returns the cache plus the next-token
logits at position ``plen - 1``; ``step_fn`` appends one token at ``pos``
and returns the logits for position ``pos + 1``.  Causality means cache
entries past the valid prefix are garbage-but-masked, never observed.
Families that do not implement the pair fall back to a pure-JAX generic
path (``seq_prefill``/``seq_step`` below) that recomputes the full forward
from a token-buffer "cache" — correct for every family, just uncached.

Params are plain nested dicts of jnp arrays; "stacked" per-layer weights carry
a leading ``layers`` logical axis and are consumed by ``lax.scan``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

Params = Any
Pytree = Any


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | whisper | rwkv6 | zamba2 | vlm
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int
    n_kv_heads: int = 0              # 0 -> = n_heads (MHA)
    d_head: int = 0                  # 0 -> d_model // n_heads

    # --- dense-family variants ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu (SwiGLU) | gelu (plain MLP)
    qkv_bias: bool = False           # qwen2
    rope_frac: float = 1.0           # stablelm-2 partial rotary (0.25)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    residual_scale: float = 1.0      # minicpm depth-scaled residuals
    logit_scale: float = 1.0         # minicpm mup output scaling

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_topk: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0      # deepseek-v2: leading dense layers
    d_ff_dense: int = 0              # d_ff of those dense layers
    router_aux_coef: float = 0.001
    moe_capacity: float = 1.25       # dropped-token dispatch capacity factor
    moe_impl: str = "gather"         # gather (E,C buffers) | ragged (sort+ragged_dot)
    moe_groups: int = 1              # GShard grouped dispatch: groups shard over data
    scan_chunk: int = 64             # chunked-recurrence length (rwkv6 / ssd)
    logits_soft_cap: float = 0.0     # grok-1 tanh attention-logit cap

    # --- MLA (deepseek-v2) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- rwkv6 ---
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32

    # --- zamba2 / mamba2 ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    shared_attn_every: int = 6       # apply shared attention block every N ssm blocks

    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 1500              # stub frame-embedding length

    # --- modality stubs ---
    n_patches: int = 0               # vlm: stub patch embeddings prepended
    frontend_dim: int = 0            # dim of stub embeddings (== d_model here)

    # --- numerics / compile strategy ---
    attn_impl: str = "sdpa"          # sdpa (materialized) | blocked (online softmax)
    seq_shard_carry: bool = False    # Megatron-SP: layer-boundary activations
                                     # (scan-saved carries) sharded over model
    attn_blk_q: int = 256
    attn_blk_k: int = 1024
    dtype: str = "bfloat16"
    remat: bool = True
    use_scan: bool = True
    ce_chunk: int = 512              # chunked cross-entropy block (tokens)
    use_pallas: bool = False         # kernel path (TPU); False = jnp reference
    max_seq: int = 8192              # rope table default cap (runtime extends)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


# ---------------------------------------------------------------------------
# family registry
# ---------------------------------------------------------------------------
_FAMILIES: Dict[str, Any] = {}


def register_family(name: str):
    def deco(mod):
        _FAMILIES[name] = mod
        return mod
    return deco


_FAMILY_MODULES = {
    "dense": "transformer",
    "moe": "moe",
    "whisper": "whisper",
    "rwkv6": "rwkv6",
    "zamba2": "zamba2",
    "vlm": "vlm",
}


def get_family(cfg_or_name):
    name = cfg_or_name.family if isinstance(cfg_or_name, ModelConfig) else cfg_or_name
    if name not in _FAMILIES:
        # import side-effect registration
        import importlib
        importlib.import_module(f"repro.models.{_FAMILY_MODULES.get(name, name)}")
    return _FAMILIES[name]


# ---------------------------------------------------------------------------
# incremental single-sequence decode (unbatched; see module docstring)
# ---------------------------------------------------------------------------
def _generic_prefill(cfg: ModelConfig, params, toks, plen):
    """Fallback prefill: the "cache" is just the token buffer itself."""
    fam = get_family(cfg)
    logits = fam.logits_fn(cfg, params, toks[None])[0]
    last = jax.lax.dynamic_index_in_dim(
        logits, jnp.asarray(plen, jnp.int32) - 1, axis=0, keepdims=False)
    return last.astype(jnp.float32), {"toks": toks.astype(jnp.int32)}


def _generic_step(cfg: ModelConfig, params, cache, tok, pos):
    """Fallback step: write ``tok`` at ``pos`` and re-run the full forward.

    Functionally identical to the cached path (same logits), with no
    compute amortization — the contract the parity tests pin down.
    """
    fam = get_family(cfg)
    pos = jnp.asarray(pos, jnp.int32)
    toks = cache["toks"].at[pos].set(jnp.asarray(tok, jnp.int32), mode="drop")
    logits = fam.logits_fn(cfg, params, toks[None])[0]
    out = jax.lax.dynamic_index_in_dim(logits, pos, axis=0, keepdims=False)
    return out.astype(jnp.float32), {"toks": toks}


def seq_prefill(cfg: ModelConfig, params, toks, plen):
    """Single-sequence prefill: ``toks [S] i32`` (padded buffer), ``plen``
    scalar true length -> ``(logits [V] f32 at plen-1, cache)``.  Dispatches
    to the family's ``prefill_fn`` when present, else the generic fallback.
    """
    fam = get_family(cfg)
    fn = getattr(fam, "prefill_fn", None)
    if fn is None:
        return _generic_prefill(cfg, params, toks, plen)
    return fn(cfg, params, toks, plen)


def seq_step(cfg: ModelConfig, params, cache, tok, pos):
    """Single-sequence incremental step: append ``tok`` (scalar i32) at
    ``pos`` -> ``(logits [V] f32 for pos+1, cache)``.  ``cache`` must come
    from ``seq_prefill`` (or a prior ``seq_step``) with the same cfg/params.
    """
    fam = get_family(cfg)
    fn = getattr(fam, "step_fn", None)
    if fn is None:
        return _generic_step(cfg, params, cache, tok, pos)
    return fn(cfg, params, cache, tok, pos)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------
def abstract_params(cfg: ModelConfig) -> Pytree:
    """ShapeDtypeStruct pytree of the params — no allocation (dry-run path)."""
    fam = get_family(cfg)
    return jax.eval_shape(lambda k: fam.init(cfg, k), jax.random.key(0))


def count_params(tree: Pytree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def active_param_count(cfg: ModelConfig) -> int:
    """Activated parameters per token (MoE discounts inactive experts)."""
    total = count_params(abstract_params(cfg))
    if cfg.n_experts and cfg.moe_topk:
        fam = get_family(cfg)
        if hasattr(fam, "inactive_expert_params"):
            total -= fam.inactive_expert_params(cfg)
    return total
