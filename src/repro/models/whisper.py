"""Whisper-style encoder-decoder (arXiv:2212.04356) — transformer backbone only.

The conv audio frontend is a STUB per assignment: ``input_specs()`` provides
precomputed frame embeddings [B, enc_seq, D].  Encoder = bidirectional
transformer with sinusoidal positions; decoder = causal transformer with
learned positions + cross-attention.  LayerNorm + GELU, pre-LN.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.base import ModelConfig, register_family


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _ln(cfg, d=None):
    d = d or cfg.d_model
    return {"scale": jnp.ones((d,), cfg.jdtype), "bias": jnp.zeros((d,), cfg.jdtype)}


def _init_enc_block(cfg: ModelConfig, key):
    ks = jax.random.split(key, 2)
    return {"ln1": _ln(cfg), "attn": L.init_gqa(cfg, ks[0]),
            "ln2": _ln(cfg), "mlp": L.init_mlp(cfg, ks[1])}


def _init_dec_block(cfg: ModelConfig, key):
    ks = jax.random.split(key, 3)
    return {"ln1": _ln(cfg), "self_attn": L.init_gqa(cfg, ks[0]),
            "ln_x": _ln(cfg), "cross_attn": L.init_gqa(cfg, ks[1]),
            "ln2": _ln(cfg), "mlp": L.init_mlp(cfg, ks[2])}


def init(cfg: ModelConfig, key):
    ks = jax.random.split(key, 5)
    enc = jax.vmap(lambda k: _init_enc_block(cfg, k))(jax.random.split(ks[0], cfg.n_enc_layers))
    dec = jax.vmap(lambda k: _init_dec_block(cfg, k))(jax.random.split(ks[1], cfg.n_layers))
    return {
        "embed": {"tok": L.embed_init(ks[2], (cfg.vocab_size, cfg.d_model), cfg.jdtype)},
        "pos_dec": L.embed_init(ks[3], (cfg.max_seq, cfg.d_model), cfg.jdtype),
        "enc_layers": enc, "ln_enc": _ln(cfg),
        "dec_layers": dec, "ln_dec": _ln(cfg),
    }


def param_axes(cfg: ModelConfig):
    ln = {"scale": (None,), "bias": (None,)}
    attn = {"wq": ("embed", "heads"), "wk": ("embed", "kv"),
            "wv": ("embed", "kv"), "wo": ("heads", "embed")}
    if cfg.qkv_bias:
        attn.update({"bq": ("heads",), "bk": ("kv",), "bv": ("kv",)})
    mlp = {"wi": ("embed", "mlp"), "bi": ("mlp",), "wo": ("mlp", "embed"), "bo": ("embed",)}
    enc_blk = {"ln1": dict(ln), "attn": dict(attn), "ln2": dict(ln), "mlp": dict(mlp)}
    dec_blk = {"ln1": dict(ln), "self_attn": dict(attn), "ln_x": dict(ln),
               "cross_attn": dict(attn), "ln2": dict(ln), "mlp": dict(mlp)}
    st = lambda t: jax.tree_util.tree_map(lambda ax: ("layers",) + ax, t,
                                          is_leaf=lambda x: isinstance(x, tuple))
    return {"embed": {"tok": ("vocab", "embed")}, "pos_dec": (None, "embed"),
            "enc_layers": st(enc_blk), "ln_enc": dict(ln),
            "dec_layers": st(dec_blk), "ln_dec": dict(ln)}


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------
def _sinusoid(length: int, d: int, dtype):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / (d // 2 - 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def encode(cfg: ModelConfig, params, frames):
    """frames [B, enc_seq, D] (stub conv output) -> encoder states."""
    b, s, d = frames.shape
    x = frames + _sinusoid(s, d, frames.dtype)[None]

    def body(carry, lp):
        from repro.parallel.sharding import with_logical_constraint
        y = with_logical_constraint(carry, ("batch", None, None))
        h = L.layernorm(y, lp["ln1"]["scale"], lp["ln1"]["bias"])
        q, k, v = L.gqa_project_qkv(cfg, lp["attn"], h)
        a = L.attention(cfg, q, k, v, causal=False)
        y = y + a.reshape(b, s, -1) @ lp["attn"]["wo"]
        h = L.layernorm(y, lp["ln2"]["scale"], lp["ln2"]["bias"])
        y = y + L.apply_mlp(cfg, lp["mlp"], h)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.layernorm(x, params["ln_enc"]["scale"], params["ln_enc"]["bias"])


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------
def _dec_block(cfg, lp, x, enc, *, self_kv=None, pos=None, kv_valid_len=None):
    """Full-seq (self_kv None) or cached single-token decode."""
    from repro.parallel.sharding import with_logical_constraint
    x = with_logical_constraint(x, ("batch", None, None))
    b, s, _ = x.shape
    h = L.layernorm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
    q, k, v = L.gqa_project_qkv(cfg, lp["self_attn"], h)
    new_kv = None
    if self_kv is not None:
        ck, cv = self_kv
        ck = ck.at[jnp.arange(b), pos].set(k[:, 0].astype(ck.dtype))
        cv = cv.at[jnp.arange(b), pos].set(v[:, 0].astype(cv.dtype))
        new_kv = (ck, cv)
        a = L.attention(cfg, q, ck, cv, causal=False, kv_valid_len=kv_valid_len)
    else:
        a = L.attention(cfg, q, k, v, causal=True)
    x = x + a.reshape(b, s, -1) @ lp["self_attn"]["wo"]
    h = L.layernorm(x, lp["ln_x"]["scale"], lp["ln_x"]["bias"])
    if isinstance(enc, tuple):                       # precomputed cross k, v
        qx = (h @ lp["cross_attn"]["wq"])
        if "bq" in lp["cross_attn"]:
            qx = qx + lp["cross_attn"]["bq"]
        qx = qx.reshape(b, s, cfg.n_heads, cfg.head_dim)
        kx, vx = enc
        a = L.attention(cfg, qx, kx, vx, causal=False)
    else:
        qx, kx, vx = _cross_qkv(cfg, lp["cross_attn"], h, enc)
        a = L.attention(cfg, qx, kx, vx, causal=False)
    x = x + a.reshape(b, s, -1) @ lp["cross_attn"]["wo"]
    h = L.layernorm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
    x = x + L.apply_mlp(cfg, lp["mlp"], h)
    return x, new_kv


def _cross_qkv(cfg, p, x, enc):
    b, s, _ = x.shape
    se = enc.shape[1]
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (enc @ p["wk"]).reshape(b, se, cfg.kv_heads, cfg.head_dim)
    v = (enc @ p["wv"]).reshape(b, se, cfg.kv_heads, cfg.head_dim)
    if "bq" in p:
        q = q + p["bq"].reshape(cfg.n_heads, cfg.head_dim)
        k = k + p["bk"].reshape(cfg.kv_heads, cfg.head_dim)
        v = v + p["bv"].reshape(cfg.kv_heads, cfg.head_dim)
    return q, k, v


def decode_states(cfg: ModelConfig, params, tokens, enc, positions=None):
    b, s = tokens.shape
    if positions is None:
        positions = jnp.arange(s)
    x = jnp.take(params["embed"]["tok"], tokens, axis=0) + params["pos_dec"][positions]

    def body(carry, lp):
        y, _ = _dec_block(cfg, lp, carry, enc)
        if cfg.seq_shard_carry:
            from repro.parallel.sharding import with_logical_constraint
            y = with_logical_constraint(y, ("batch", "act_seq", None))
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return L.layernorm(x, params["ln_dec"]["scale"], params["ln_dec"]["bias"])


def loss_fn(cfg: ModelConfig, params, batch, rng=None):
    enc = encode(cfg, params, batch["frames"])
    x = decode_states(cfg, params, batch["tokens"], enc)
    loss = L.chunked_softmax_xent(cfg, params["embed"], x, batch["labels"],
                                  batch.get("mask"))
    return loss, {"loss": loss}


def logits_fn(cfg: ModelConfig, params, tokens, frames):
    enc = encode(cfg, params, frames)
    x = decode_states(cfg, params, tokens, enc)
    return x @ params["embed"]["tok"].T          # tied head


# ---------------------------------------------------------------------------
# inference (cache: decoder self-attn KV + precomputed cross KV)
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int, dtype=None):
    dtype = dtype or cfg.jdtype
    kv = (cfg.n_layers, batch_size, max_seq, cfg.kv_heads, cfg.head_dim)
    xkv = (cfg.n_layers, batch_size, cfg.enc_seq, cfg.kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
            "xk": jnp.zeros(xkv, dtype), "xv": jnp.zeros(xkv, dtype),
            "pos": jnp.zeros((batch_size,), jnp.int32)}


def cache_axes(cfg: ModelConfig):
    return {"k": ("layers", "batch", "kv_seq", "kv", None),
            "v": ("layers", "batch", "kv_seq", "kv", None),
            "xk": ("layers", "batch", None, "kv", None),
            "xv": ("layers", "batch", None, "kv", None),
            "pos": ("batch",)}


def prefill(cfg: ModelConfig, params, batch, cache):
    """batch {frames, tokens} -> (last logits, cache with cross+self KV)."""
    frames, tokens = batch["frames"], batch["tokens"]
    b, s = tokens.shape
    enc = encode(cfg, params, frames)

    def xkv(lp):
        _, k, v = _cross_qkv(cfg, lp["cross_attn"], enc[:, :1], enc)
        return k, v
    xks, xvs = jax.lax.map(xkv, params["dec_layers"])

    def body(carry, lp):
        y = carry
        h = L.layernorm(y, lp["ln1"]["scale"], lp["ln1"]["bias"])
        q, k, v = L.gqa_project_qkv(cfg, lp["self_attn"], h)
        a = L.attention(cfg, q, k, v, causal=True)
        y = y + a.reshape(b, s, -1) @ lp["self_attn"]["wo"]
        h = L.layernorm(y, lp["ln_x"]["scale"], lp["ln_x"]["bias"])
        qx, kx, vx = _cross_qkv(cfg, lp["cross_attn"], h, enc)
        a = L.attention(cfg, qx, kx, vx, causal=False)
        y = y + a.reshape(b, s, -1) @ lp["cross_attn"]["wo"]
        h = L.layernorm(y, lp["ln2"]["scale"], lp["ln2"]["bias"])
        y = y + L.apply_mlp(cfg, lp["mlp"], h)
        return y, (k, v)

    x = jnp.take(params["embed"]["tok"], tokens, axis=0) + params["pos_dec"][jnp.arange(s)]
    x, (ks, vs) = jax.lax.scan(body, x, params["dec_layers"])
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(cache["k"], ks.astype(cache["k"].dtype), (0,) * 5)
    cache["v"] = jax.lax.dynamic_update_slice(cache["v"], vs.astype(cache["v"].dtype), (0,) * 5)
    cache["xk"], cache["xv"] = xks.astype(cache["xk"].dtype), xvs.astype(cache["xv"].dtype)
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    x = L.layernorm(x, params["ln_dec"]["scale"], params["ln_dec"]["bias"])
    return x[:, -1:] @ params["embed"]["tok"].T, cache


def decode_step(cfg: ModelConfig, params, cache, tokens):
    b = tokens.shape[0]
    pos = cache["pos"]
    valid = pos + 1
    x = jnp.take(params["embed"]["tok"], tokens, axis=0) + params["pos_dec"][pos][:, None]

    def body(carry, xs):
        lp, ck, cv, xk, xv = xs
        y, new_kv = _dec_block(cfg, lp, carry, (xk, xv), self_kv=(ck, cv),
                               pos=pos, kv_valid_len=valid)
        return y, new_kv

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    cache = dict(cache)
    cache["k"], cache["v"] = ks, vs
    cache["pos"] = pos + 1
    x = L.layernorm(x, params["ln_dec"]["scale"], params["ln_dec"]["bias"])
    return x @ params["embed"]["tok"].T, cache


register_family("whisper")(__import__("sys").modules[__name__])
