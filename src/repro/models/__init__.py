from repro.models.base import (  # noqa: F401
    ModelConfig,
    abstract_params,
    active_param_count,
    count_params,
    get_family,
    register_family,
)
