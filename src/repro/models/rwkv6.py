"""RWKV-6 "Finch" (arXiv:2404.05892) — attention-free RNN LM.

Block = TimeMix (WKV6 recurrence, data-dependent per-channel decay via LoRA)
      + ChannelMix (squared-ReLU FFN with token-shift).

State per layer: WKV state [B, H, N, N] + two token-shift slots [B, D]
(time-mix and channel-mix).  Decode is O(1) in context length — the
``long_500k`` cell runs with constant memory/compute per token.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan import ops as wkv_ops
from repro.models import layers as L
from repro.models.base import ModelConfig, register_family


def _heads(cfg: ModelConfig):
    n = cfg.rwkv_head_dim
    return cfg.d_model // n, n


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_block(cfg: ModelConfig, key):
    d = cfg.d_model
    h, n = _heads(cfg)
    lm, ld = cfg.rwkv_mix_lora, cfg.rwkv_decay_lora
    ks = jax.random.split(key, 12)
    dt = cfg.jdtype
    tm = {
        "maa_x": jnp.zeros((d,), dt),
        "maa_rkvwg": jnp.zeros((5, d), dt),
        "maa_w1": L.dense_init(ks[0], (d, 5 * lm), dt),
        "maa_w2": L.dense_init(ks[1], (5, lm, d), dt, in_axis=1),
        "decay": jnp.full((d,), -6.0, dt),
        "decay_w1": L.dense_init(ks[2], (d, ld), dt),
        "decay_w2": L.dense_init(ks[3], (ld, d), dt),
        "faaaa": jnp.full((h, n), 0.5, dt),
        "wr": L.dense_init(ks[4], (d, d), dt),
        "wk": L.dense_init(ks[5], (d, d), dt),
        "wv": L.dense_init(ks[6], (d, d), dt),
        "wg": L.dense_init(ks[7], (d, d), dt),
        "wo": L.dense_init(ks[8], (d, d), dt),
        "ln_x_scale": jnp.ones((d,), dt),
        "ln_x_bias": jnp.zeros((d,), dt),
    }
    cm = {
        "maa_k": jnp.zeros((d,), dt),
        "maa_r": jnp.zeros((d,), dt),
        "wk": L.dense_init(ks[9], (d, cfg.d_ff), dt),
        "wv": L.dense_init(ks[10], (cfg.d_ff, d), dt),
        "wr": L.dense_init(ks[11], (d, d), dt),
    }
    ln = {"scale": jnp.ones((d,), dt), "bias": jnp.zeros((d,), dt)}
    return {"ln1": dict(ln), "time_mix": tm, "ln2": dict(ln), "channel_mix": cm}


def init(cfg: ModelConfig, key):
    k_emb, k_layers, k_f = jax.random.split(key, 3)
    stacked = jax.vmap(lambda k: _init_block(cfg, k))(jax.random.split(k_layers, cfg.n_layers))
    d = cfg.d_model
    return {
        "embed": L.init_embed(cfg, k_emb),
        "ln0": {"scale": jnp.ones((d,), cfg.jdtype), "bias": jnp.zeros((d,), cfg.jdtype)},
        "layers": stacked,
        "final_norm": {"scale": jnp.ones((d,), cfg.jdtype), "bias": jnp.zeros((d,), cfg.jdtype)},
    }


def param_axes(cfg: ModelConfig):
    ln = {"scale": (None,), "bias": (None,)}
    tm = {"maa_x": (None,), "maa_rkvwg": (None, None),
          "maa_w1": ("embed", None), "maa_w2": (None, None, "embed"),
          "decay": (None,), "decay_w1": ("embed", None), "decay_w2": (None, "embed"),
          "faaaa": ("heads", None),
          "wr": ("embed", "heads"), "wk": ("embed", "heads"),
          "wv": ("embed", "heads"), "wg": ("embed", "heads"),
          "wo": ("heads", "embed"), "ln_x_scale": (None,), "ln_x_bias": (None,)}
    cm = {"maa_k": (None,), "maa_r": (None,), "wk": ("embed", "mlp"),
          "wv": ("mlp", "embed"), "wr": ("embed", "heads")}
    blk = {"ln1": dict(ln), "time_mix": tm, "ln2": dict(ln), "channel_mix": cm}
    stack = jax.tree_util.tree_map(lambda ax: ("layers",) + ax, blk,
                                   is_leaf=lambda x: isinstance(x, tuple))
    emb = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        emb["head"] = ("embed", "vocab")
    return {"embed": emb, "ln0": dict(ln), "layers": stack, "final_norm": dict(ln)}


# ---------------------------------------------------------------------------
# block forward (sequence mode: token shift via roll; state mode for decode)
# ---------------------------------------------------------------------------
def _ddlerp(p, x, x_prev):
    """Data-dependent lerp producing the 5 mixed inputs (r,k,v,w,g)."""
    xx = x_prev - x
    xxx = x + xx * p["maa_x"]
    b, s, d = x.shape
    lo = jnp.tanh(xxx @ p["maa_w1"]).reshape(b, s, 5, -1)         # [B,S,5,lm]
    mods = jnp.einsum("bsfl,fld->fbsd", lo, p["maa_w2"])          # [5,B,S,D]
    mix = p["maa_rkvwg"][:, None, None, :] + mods
    return x[None] + xx[None] * mix                                # [5,B,S,D]


def _time_mix(cfg: ModelConfig, p, x, x_prev, wkv_state, *, use_pallas=False):
    """x [B,S,D]; x_prev [B,S,D] (token-shifted); wkv_state [B,H,N,N]."""
    b, s, d = x.shape
    h, n = _heads(cfg)
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev)
    r = (xr @ p["wr"]).reshape(b, s, h, n)
    k = (xk @ p["wk"]).reshape(b, s, h, n)
    v = (xv @ p["wv"]).reshape(b, s, h, n)
    g = jax.nn.silu((xg @ p["wg"]).astype(jnp.float32)).astype(x.dtype)
    w_raw = p["decay"].astype(jnp.float32) + \
        (jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_raw)).reshape(b, s, h, n)               # decay in (0,1)
    u = p["faaaa"]
    y, new_state = wkv_ops.wkv6(r, k, v, w, u, wkv_state, use_pallas=use_pallas)
    # per-head groupnorm
    y32 = y.astype(jnp.float32).reshape(b, s, h, n)
    mu = y32.mean(-1, keepdims=True)
    var = y32.var(-1, keepdims=True)
    y32 = (y32 - mu) * jax.lax.rsqrt(var + 1e-5)
    y = (y32.reshape(b, s, d) * p["ln_x_scale"].astype(jnp.float32)
         + p["ln_x_bias"].astype(jnp.float32)).astype(x.dtype)
    return (y * g) @ p["wo"], new_state


def _channel_mix(cfg: ModelConfig, p, x, x_prev):
    xx = x_prev - x
    xk = x + xx * p["maa_k"]
    xr = x + xx * p["maa_r"]
    k = jnp.square(jax.nn.relu((xk @ p["wk"]).astype(jnp.float32))).astype(x.dtype)
    return jax.nn.sigmoid((xr @ p["wr"]).astype(jnp.float32)).astype(x.dtype) * (k @ p["wv"])


def _shift_seq(x, first):
    """Token shift: x_prev[t] = x[t-1]; x_prev[0] = first (carried state)."""
    return jnp.concatenate([first[:, None], x[:, :-1]], axis=1)


def _block_seq(cfg: ModelConfig, lp, x, state):
    """Full-sequence block. state = {wkv, tm_prev [B,D], cm_prev [B,D]}."""
    from repro.parallel.sharding import with_logical_constraint
    x = with_logical_constraint(x, ("batch", None, None))
    h1 = L.layernorm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
    prev = _shift_seq(h1, state["tm_prev"])
    out, wkv = _time_mix(cfg, lp["time_mix"], h1, prev, state["wkv"],
                         use_pallas=cfg.use_pallas)
    x = x + out
    h2 = L.layernorm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
    prev2 = _shift_seq(h2, state["cm_prev"])
    x = x + _channel_mix(cfg, lp["channel_mix"], h2, prev2)
    new_state = {"wkv": wkv, "tm_prev": h1[:, -1], "cm_prev": h2[:, -1]}
    return x, new_state


def init_state(cfg: ModelConfig, batch_size: int, dtype=None):
    h, n = _heads(cfg)
    d = cfg.d_model
    return {
        "wkv": jnp.zeros((cfg.n_layers, batch_size, h, n, n), jnp.float32),
        "tm_prev": jnp.zeros((cfg.n_layers, batch_size, d), cfg.jdtype),
        "cm_prev": jnp.zeros((cfg.n_layers, batch_size, d), cfg.jdtype),
        "pos": jnp.zeros((batch_size,), jnp.int32),
    }


def cache_axes(cfg: ModelConfig):
    return {"wkv": ("layers", "batch", "heads", None, None),
            "tm_prev": ("layers", "batch", None),
            "cm_prev": ("layers", "batch", None),
            "pos": ("batch",)}


init_cache = lambda cfg, batch_size, max_seq, dtype=None: init_state(cfg, batch_size, dtype)


def _run(cfg: ModelConfig, params, x, state):
    def body(carry, xs):
        x = carry
        lp, st = xs
        x, new_st = _block_seq(cfg, lp, x, st)
        if cfg.seq_shard_carry and x.shape[1] > 1:
            from repro.parallel.sharding import with_logical_constraint
            x = with_logical_constraint(x, ("batch", "act_seq", None))
        return x, new_st

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    layer_states = {k: state[k] for k in ("wkv", "tm_prev", "cm_prev")}
    x, new_states = jax.lax.scan(body, x, (params["layers"], layer_states))
    return x, new_states


def hidden_states(cfg: ModelConfig, params, tokens, state=None):
    b = tokens.shape[0]
    x = L.embed_tokens(cfg, params["embed"], tokens)
    x = L.layernorm(x, params["ln0"]["scale"], params["ln0"]["bias"])
    state = state or init_state(cfg, b)
    x, new_states = _run(cfg, params, x, state)
    return L.layernorm(x, params["final_norm"]["scale"], params["final_norm"]["bias"]), new_states


def loss_fn(cfg: ModelConfig, params, batch, rng=None):
    x, _ = hidden_states(cfg, params, batch["tokens"])
    loss = L.chunked_softmax_xent(cfg, params["embed"], x, batch["labels"],
                                  batch.get("mask"))
    return loss, {"loss": loss}


def logits_fn(cfg: ModelConfig, params, tokens):
    x, _ = hidden_states(cfg, params, tokens)
    return L.lm_head(cfg, params["embed"], x)


def prefill(cfg: ModelConfig, params, tokens, cache):
    b, s = tokens.shape
    x, new_states = hidden_states(cfg, params, tokens)
    new_cache = dict(new_states)
    new_cache["pos"] = jnp.full((b,), s, jnp.int32)
    return L.lm_head(cfg, params["embed"], x[:, -1:]), new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens):
    """tokens [B,1] -> (logits, state). O(1) per token."""
    b = tokens.shape[0]
    x = L.embed_tokens(cfg, params["embed"], tokens)
    x = L.layernorm(x, params["ln0"]["scale"], params["ln0"]["bias"])
    state = {k: cache[k] for k in ("wkv", "tm_prev", "cm_prev")}
    x, new_states = _run(cfg, params, x, state)
    x = L.layernorm(x, params["final_norm"]["scale"], params["final_norm"]["bias"])
    out = dict(new_states)
    out["pos"] = cache["pos"] + 1
    return L.lm_head(cfg, params["embed"], x), out


register_family("rwkv6")(__import__("sys").modules[__name__])
