"""InternVL2-style VLM (arXiv:2404.16821): stub ViT frontend + LM backbone.

The vision tower is a STUB per assignment: ``input_specs()`` provides
precomputed patch features [B, n_patches, frontend_dim] (InternViT outputs).
This module owns the real LM-side pieces: the 2-layer MLP projector ("mlp1")
and the InternLM2 decoder backbone (dense family re-used).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as dense
from repro.models.base import ModelConfig, register_family


def init(cfg: ModelConfig, key):
    k_lm, k_p = jax.random.split(key)
    ks = jax.random.split(k_p, 2)
    fd = cfg.frontend_dim or cfg.d_model
    p = dense.init(cfg, k_lm)
    p["projector"] = {
        "ln": {"scale": jnp.ones((fd,), cfg.jdtype), "bias": jnp.zeros((fd,), cfg.jdtype)},
        "w1": L.dense_init(ks[0], (fd, cfg.d_model), cfg.jdtype),
        "b1": jnp.zeros((cfg.d_model,), cfg.jdtype),
        "w2": L.dense_init(ks[1], (cfg.d_model, cfg.d_model), cfg.jdtype),
        "b2": jnp.zeros((cfg.d_model,), cfg.jdtype),
    }
    return p


def param_axes(cfg: ModelConfig):
    ax = dense.param_axes(cfg)
    ax["projector"] = {
        "ln": {"scale": (None,), "bias": (None,)},
        "w1": (None, "embed"), "b1": ("embed",),
        "w2": ("embed", "embed"), "b2": ("embed",),
    }
    return ax


def project_patches(cfg: ModelConfig, params, patches):
    p = params["projector"]
    x = L.layernorm(patches, p["ln"]["scale"], p["ln"]["bias"])
    x = jax.nn.gelu((x @ p["w1"] + p["b1"]).astype(jnp.float32)).astype(patches.dtype)
    return x @ p["w2"] + p["b2"]


def multimodal_embeds(cfg: ModelConfig, params, patches, tokens):
    img = project_patches(cfg, params, patches)              # [B,P,D]
    txt = L.embed_tokens(cfg, params["embed"], tokens)       # [B,St,D]
    return jnp.concatenate([img, txt], axis=1)


def loss_fn(cfg: ModelConfig, params, batch, rng=None):
    """batch: patches [B,P,fd], tokens [B,St], labels [B,P+St] (-mask img pos)."""
    embeds = multimodal_embeds(cfg, params, batch["patches"], batch["tokens"])
    x = dense.hidden_states(cfg, params, inputs_embeds=embeds)
    n_img = batch["patches"].shape[1]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.concatenate([
            jnp.zeros((x.shape[0], n_img), jnp.float32),
            jnp.ones((x.shape[0], x.shape[1] - n_img), jnp.float32)], axis=1)
    loss = L.chunked_softmax_xent(cfg, params["embed"], x, batch["labels"], mask)
    return loss, {"loss": loss}


def logits_fn(cfg: ModelConfig, params, tokens):
    return dense.logits_fn(cfg, params, tokens)


def multimodal_logits(cfg: ModelConfig, params, patches, tokens):
    embeds = multimodal_embeds(cfg, params, patches, tokens)
    x = dense.hidden_states(cfg, params, inputs_embeds=embeds)
    return L.lm_head(cfg, params["embed"], x)


# inference delegates to the dense backbone (image prefix enters via prefill)
init_cache = dense.init_cache
cache_axes = dense.cache_axes
decode_step = dense.decode_step


def prefill(cfg: ModelConfig, params, tokens, cache):
    return dense.prefill(cfg, params, tokens, cache)


register_family("vlm")(__import__("sys").modules[__name__])
