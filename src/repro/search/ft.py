"""Elastic fault-tolerant multi-root search (DESIGN.md §13).

The paper's root parallelism is naturally failure-tolerant: the B searches
are independent and only merged at the end, so losing a host must cost only
that host's *in-flight* roots — never the job.  ``ElasticSearchDriver``
makes that concrete:

* roots are partitioned into per-host work queues (a "host" is a logical
  worker owning a slice of the mesh's devices; in a ``jax.distributed`` job
  the slices line up with processes);
* each host runs its queue in chunks through the same per-root program as
  ``search_batch`` — under the root's ORIGINAL key, split from the driver
  rng into exactly B keys before any partitioning — so every committed root
  is bit-for-bit identical to an uninterrupted run;
* a lost host (``runtime.ft.SimulatedFailure``) or a stalled one (detected
  by ``runtime.ft.Heartbeat``'s watchdog) is removed from the world: its
  in-flight roots are requeued onto survivors, its unstarted queue is
  redistributed, and its devices are dropped from the mesh
  (``runtime.elastic.shrink_mesh``) so subsequent placement targets the
  shrunken world;
* completed-root results are committed through ``checkpoint.store`` (atomic
  rename + COMMITTED marker, keep-N) — a *driver* restart with the same
  ``ckpt_dir`` resumes from committed roots and re-runs only the rest.

Deterministic failure injection is part of the public surface (the
``runtime.ft.FTConfig`` idiom): ``kill_host_at_root=N`` kills the host that
owns root N the moment it launches a chunk containing N;
``stall_host_at_root=K`` hangs that host past the watchdog instead.  Each
fires at most once, so a requeued root does not re-trigger the failure —
and a failure point that is already committed (or never launched) is a
no-op.  The fault-injection suite (tests/test_search_ft.py) drives every
contract above through these two knobs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import numpy as np

from repro.runtime.ft import Heartbeat, SimulatedFailure, WatchdogTimeout

__all__ = ["FTSearchConfig", "FTReport", "ElasticSearchDriver",
           "ft_search_batch"]


@dataclasses.dataclass(frozen=True)
class FTSearchConfig:
    """Elastic-driver knobs + deterministic failure injection.

    hosts:            logical workers the roots are partitioned over
                      (clamped to B).
    chunk:            roots a host launches per round (0 = its whole queue).
    watchdog_s:       per-host heartbeat timeout (runtime.ft.Heartbeat).
    stall_s:          injected stall duration (0 -> 3x watchdog_s).
    ckpt_dir:         commit completed roots here (None = no checkpointing).
    ckpt_keep:        keep-N for committed checkpoints.
    max_requeues:     per-root requeue budget before the driver gives up.
    partition_seed:   None = contiguous blocks; int = seeded shuffle of the
                      root->host assignment.
    requeue_seed:     None = requeue victims onto survivors round-robin in
                      root order; int = seeded shuffle first (merge results
                      are invariant to this — tests/test_properties.py).
    kill_host_at_root / stall_host_at_root:  failure injection, see module
                      docstring.  Each fires at most once per run.
    """

    hosts: int = 1
    chunk: int = 0
    watchdog_s: float = 5.0
    stall_s: float = 0.0
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    max_requeues: int = 2
    partition_seed: Optional[int] = None
    requeue_seed: Optional[int] = None
    kill_host_at_root: Optional[int] = None
    stall_host_at_root: Optional[int] = None


@dataclasses.dataclass
class FTReport:
    """What the run actually did (the fault-injection suite's oracle)."""

    runs: np.ndarray                    # [B] launches per root
    requeued: List[int]                 # in-flight roots re-run after a loss
    lost_hosts: List[int]               # logical hosts removed from the world
    resumed: List[int]                  # roots restored from a checkpoint
    rounds: int = 0
    commits: int = 0


class ElasticSearchDriver:
    """Requeue-and-shrink driver over per-host work queues (see module doc).

    ``mesh=None`` runs each chunk locally (the plain vmap path — in a
    multi-process job every process then computes the same chunks, which
    keeps the processes in lockstep without collectives); pass a 1-D mesh to
    partition its devices among the hosts and run each chunk through
    ``shard_search_keys`` on the owner's slice.
    """

    def __init__(self, domains, cfg, rng, ft: Optional[FTSearchConfig] = None,
                 *, mesh=None):
        self.domains = list(domains)
        if not self.domains:
            raise ValueError("ft_search_batch needs at least one domain")
        b = len(self.domains)
        self.cfg = cfg
        self.ft = ft or FTSearchConfig()
        # rng contract: exactly B keys, split before partitioning/placement —
        # the invariant that makes requeue/merge bitwise-exact
        self.keys = jax.random.split(rng, b)
        self.mesh = mesh
        hosts = max(1, min(self.ft.hosts, b))
        order = np.arange(b)
        if self.ft.partition_seed is not None:
            order = np.random.RandomState(self.ft.partition_seed)\
                .permutation(b)
        self.queues: List[List[int]] = [
            [int(i) for i in q] for q in np.array_split(order, hosts)]
        self.alive = [True] * hosts
        self._host_devices = self._partition_devices(mesh, hosts)
        self._done = np.zeros(b, bool)
        self._acc = None                          # [B,...] result accumulator
        self._requeues = np.zeros(b, np.int32)
        self._fired = {"kill": False, "stall": False}
        self.report = FTReport(runs=np.zeros(b, np.int64), requeued=[],
                               lost_hosts=[], resumed=[])
        if self.ft.ckpt_dir:
            self._try_resume()

    # -- placement ---------------------------------------------------------
    @staticmethod
    def _partition_devices(mesh, hosts: int):
        if mesh is None:
            return [None] * hosts
        devs = list(mesh.devices.flat)
        return [list(s) for s in np.array_split(np.asarray(devs, object),
                                                hosts)]

    def _host_mesh(self, h: int):
        from repro.parallel.compat import mesh_from_devices
        devs = self._host_devices[h]
        if not devs:
            return None
        return mesh_from_devices(devs)

    def _shrink(self, lost: int) -> None:
        """Drop ``lost``'s devices and re-place the surviving hosts over the
        shrunken world (reshard_state-style: subsequent chunks target the new
        meshes; committed results already live on the host)."""
        if self.mesh is None:
            return
        from repro.runtime.elastic import shrink_mesh
        self.mesh = shrink_mesh(self.mesh, self._host_devices[lost] or [])
        self._host_devices[lost] = []
        survivors = [h for h in range(len(self.alive)) if self.alive[h]]
        if self.mesh is None:
            for h in survivors:
                self._host_devices[h] = []
            return
        keep = np.asarray(list(self.mesh.devices.flat), object)
        for h, sl in zip(survivors, np.array_split(keep, len(survivors))):
            self._host_devices[h] = list(sl)

    # -- checkpointing -----------------------------------------------------
    def _template(self):
        """[B, ...] zeroed accumulator with the exact result structure
        (eval_shape: no compute)."""
        from repro.search.api import search
        b = len(self.domains)
        one = jax.eval_shape(
            lambda k: search(self.domains[0], self.cfg, k), self.keys[0])
        return jax.tree_util.tree_map(
            lambda s: np.zeros((b,) + tuple(s.shape), s.dtype), one)

    def _try_resume(self) -> None:
        from repro.checkpoint import store
        step = store.latest_step(self.ft.ckpt_dir)
        if step is None:
            return
        like = {"done": np.zeros(len(self.domains), bool),
                "results": self._template()}
        state = store.restore(self.ft.ckpt_dir, step, like)
        self._done = np.asarray(state["done"], bool).copy()
        self._acc = state["results"]
        self.report.resumed = [int(i) for i in np.nonzero(self._done)[0]]

    def _commit(self, roots: List[int], res) -> None:
        if self._acc is None:
            # shape the accumulator off the first result instead of
            # _template(): eval_shape re-traces the whole search program,
            # which on the zero-failure path is pure driver overhead
            # (benchmarks/ft_overhead.py gates it at <=5%)
            b = len(self.domains)
            self._acc = jax.tree_util.tree_map(
                lambda x: np.zeros((b,) + tuple(x.shape[1:]), x.dtype), res)
        flat_acc = jax.tree_util.tree_leaves(self._acc)
        flat_res = jax.tree_util.tree_leaves(res)
        for acc, leaf in zip(flat_acc, flat_res):
            rows = np.asarray(leaf)[:len(roots)]
            acc[np.asarray(roots)] = rows
        self._done[np.asarray(roots)] = True
        self.report.commits += 1
        if self.ft.ckpt_dir:
            from repro.checkpoint import store
            store.save(self.ft.ckpt_dir, self.report.commits,
                       {"done": self._done, "results": self._acc},
                       keep=self.ft.ckpt_keep)

    # -- execution ---------------------------------------------------------
    def _execute(self, h: int, roots: List[int]):
        from repro.search.api import _batch_domains, search
        from repro.search.sharding import shard_search_keys
        doms = [self.domains[i] for i in roots]
        keys = self.keys[np.asarray(roots)]
        hmesh = self._host_mesh(h)
        if hmesh is not None:
            return shard_search_keys(doms, self.cfg, keys, mesh=hmesh)
        make, batched = _batch_domains(doms)
        if batched is None:
            return jax.vmap(lambda r: search(doms[0], self.cfg, r))(keys)
        return jax.vmap(
            lambda bat, r: search(make(bat), self.cfg, r))(batched, keys)

    def _launch(self, h: int, roots: List[int]) -> None:
        ft = self.ft
        self.report.runs[np.asarray(roots)] += 1
        if (not self._fired["kill"] and ft.kill_host_at_root is not None
                and ft.kill_host_at_root in roots):
            self._fired["kill"] = True
            raise SimulatedFailure(
                f"injected kill of host {h} at root {ft.kill_host_at_root}")
        # The watchdog is scoped to this launch (the hosts are simulated on
        # one driver thread, so a long-lived per-host heartbeat would expire
        # on every OTHER host while one stalls) and polices the dispatch
        # window, not device compute: a hung host never issues its launch, a
        # healthy one beats immediately — compile time must not look like a
        # hang under the short watchdogs the deterministic tests use.
        hb = Heartbeat(ft.watchdog_s)
        try:
            if (not self._fired["stall"]
                    and ft.stall_host_at_root is not None
                    and ft.stall_host_at_root in roots):
                self._fired["stall"] = True
                time.sleep(ft.stall_s or 3.0 * ft.watchdog_s)
            hb.beat()           # raises WatchdogTimeout if the host stalled
        finally:
            hb.stop()
        self._commit(roots, self._execute(h, roots))

    def _on_host_lost(self, h: int, inflight: List[int]) -> None:
        self.alive[h] = False
        self.report.lost_hosts.append(h)
        survivors = [s for s in range(len(self.alive)) if self.alive[s]]
        if not survivors:
            raise RuntimeError(
                f"all {len(self.alive)} hosts lost; cannot finish "
                f"{int((~self._done).sum())} roots")
        victims = [i for i in inflight if not self._done[i]]
        self._requeues[np.asarray(victims, int)] += 1
        over = [i for i in victims
                if self._requeues[i] > self.ft.max_requeues]
        if over:
            raise RuntimeError(f"roots {over} exceeded max_requeues="
                               f"{self.ft.max_requeues}")
        self.report.requeued.extend(victims)
        # in-flight roots first (they were launched and lost), then the dead
        # host's unstarted queue; spread over survivors round-robin
        orphans = victims + [i for i in self.queues[h] if not self._done[i]]
        self.queues[h] = []
        if self.ft.requeue_seed is not None:
            orphans = [orphans[j] for j in np.random.RandomState(
                self.ft.requeue_seed).permutation(len(orphans))]
        for j, i in enumerate(orphans):
            self.queues[survivors[j % len(survivors)]].append(i)
        self._shrink(h)

    # -- main loop ---------------------------------------------------------
    def run(self, max_rounds: Optional[int] = None):
        """Drive every root to a committed result; returns the merged
        ``SearchResult`` (numpy leaves), bit-for-bit equal per root to the
        uninterrupted ``search_batch`` run.  ``max_rounds`` bounds the number
        of scheduling rounds (for restart tests); when it stops early the
        partial state is committed and ``None`` is returned."""
        rounds = 0
        while not self._done.all():
            if max_rounds is not None and rounds >= max_rounds:
                return None
            progressed = False
            for h in range(len(self.alive)):
                if not self.alive[h]:
                    continue
                queue = [i for i in self.queues[h] if not self._done[i]]
                take = self.ft.chunk or len(queue)
                roots, self.queues[h] = queue[:take], queue[take:]
                if not roots:
                    continue
                progressed = True
                try:
                    self._launch(h, roots)
                except (SimulatedFailure, WatchdogTimeout):
                    self._on_host_lost(h, roots)
            rounds += 1
            self.report.rounds = rounds
            if not progressed:
                raise RuntimeError("no progress: live hosts have empty "
                                   "queues but roots remain")
        return self.result()

    def result(self):
        """Merged result for the committed roots (full ``SearchResult`` once
        ``run()`` finished)."""
        if self._acc is None:
            raise RuntimeError("no roots committed yet")
        return self._acc


def ft_search_batch(domains, cfg, rng, *,
                    ft: Optional[FTSearchConfig] = None, mesh=None):
    """``search_batch`` under the elastic driver: same per-root results
    (bit-for-bit, even across injected host loss), committed through the
    checkpoint store when ``ft.ckpt_dir`` is set."""
    return ElasticSearchDriver(domains, cfg, rng, ft, mesh=mesh).run()
