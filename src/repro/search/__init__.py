"""repro.search — the single public API for all MCTS parallelizations.

    from repro.search import SearchConfig, SearchParams, search, search_batch

    res = search(domain, SearchConfig(method="pipeline", budget=256,
                                      lanes=8), jax.random.key(0))

Entry points
    search(domain, cfg, rng)            one search, jit/vmap-compatible
    search_batch(domains, cfg, rng)     B searches in ONE device program
                                        (auto-shards over a device mesh)
    shard_search_batch(...)             the explicit mesh-sharded form
                                        (single- or multi-host meshes)
    ft_search_batch(...)                the elastic fault-tolerant driver
                                        (requeue-and-shrink; DESIGN §13)

Configuration
    SearchConfig    method/budget/lanes/max_nodes/keep_tree + ``params``
    SearchParams    UCT knobs: cp, vl_weight, max_depth, puct, use_pallas,
                    wave_select ("scan" | "lockstep" | "auto" — DESIGN §11)

Extension points
    Domain          structural protocol every strategy accepts
    SupportsPriors  optional PUCT-priors extension; check_domain(d) validates
    register_strategy(name)  add a parallelization; list_strategies() names
                    the built-ins: sequential, root, leaf, tree, pipeline

Results
    SearchResult    action_visits / action_value / best_action / tree /
                    stats (always exactly STATS_KEYS) / extras

See README.md (quickstart), DESIGN.md §3–§5 (API design), §9 (sharding),
§11 (lockstep wave selection).
"""
from repro.core.stages import SearchParams  # noqa: F401  (re-export)
from repro.search.api import (STATS_KEYS, SearchConfig,  # noqa: F401
                              SearchResult, get_strategy, list_strategies,
                              register_strategy, search, search_batch)
from repro.search.domain import (Domain, SupportsPriors,  # noqa: F401
                                 check_domain)
from repro.search.sharding import (shard_search_batch,  # noqa: F401
                                   shard_search_keys)
from repro.search.ft import (ElasticSearchDriver, FTReport,  # noqa: F401
                             FTSearchConfig, ft_search_batch)
from repro.search import strategies  # noqa: F401  (registers the built-ins)

__all__ = [
    "STATS_KEYS", "SearchConfig", "SearchParams", "SearchResult",
    "Domain", "SupportsPriors", "check_domain",
    "search", "search_batch", "shard_search_batch", "shard_search_keys",
    "ElasticSearchDriver", "FTReport", "FTSearchConfig", "ft_search_batch",
    "get_strategy", "list_strategies", "register_strategy",
    "strategies",
]
