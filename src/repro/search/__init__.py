"""repro.search — the single public API for all MCTS parallelizations.

    from repro.search import SearchConfig, search, search_batch

See DESIGN.md §3–§5 and ``repro.search.api``.
"""
from repro.core.stages import SearchParams  # noqa: F401  (re-export)
from repro.search.api import (STATS_KEYS, SearchConfig,  # noqa: F401
                              SearchResult, get_strategy, list_strategies,
                              register_strategy, search, search_batch)
from repro.search.domain import (Domain, SupportsPriors,  # noqa: F401
                                 check_domain)
from repro.search.sharding import shard_search_batch  # noqa: F401
from repro.search import strategies  # noqa: F401  (registers the built-ins)
