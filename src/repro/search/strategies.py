"""The five built-in strategies, registered under their paper names.

Each is the canonical implementation (the old ``core.run_*`` entry points
are now deprecated shims over these).  All share:

* ``cfg.lanes`` as the single degree-of-parallelism knob (pipeline lanes ==
  tree-parallel threads == root/leaf workers);
* the common stats schema (api.STATS_KEYS), with ``playouts_requested`` the
  budget after lane rounding and ``playouts_completed`` the backups actually
  applied — the pipeline counts completions per tick, the others complete
  exactly what they request.  ``duplicates`` means exactly one thing for
  every strategy: the selected leaf already had in-flight playouts when the
  lane arrived (pre-wave in-flight count > 0, or a lower-numbered lane of
  the same wave picked the same leaf).  Single-trajectory strategies
  (sequential / root / leaf) measure the same event — it is provably always
  zero for them, and tests assert that;
* ``SearchResult`` assembly via ``api.result_from_tree``.

Paper mapping (§IV baselines + §V contribution):
  sequential — Fig. 1 S→E→P→B loop (strength reference)
  root       — Ensemble UCT: independent trees, root stats summed
  leaf       — one trajectory, ``lanes`` parallel playouts from its leaf
  tree       — shared tree + virtual loss, ``lanes`` trajectories per round
  pipeline   — the paper's software-pipelined MCTS (linear/nonlinear)

``tree`` and ``pipeline`` waves select through ``core.stages.select_wave``,
so ``SearchParams.wave_select`` switches their Select stage between the
lane-major scan and the depth-major lockstep path (one batched UCT pass per
tree level — DESIGN.md §11) without touching this module.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import stages as S
from repro.core.tree import init_tree, root_child_stats
from repro.search.api import (SearchConfig, SearchResult, make_stats,
                              register_strategy, result_from_tree)

__all__ = ["PIPE_STAGES", "sequential", "root", "leaf", "tree_parallel",
           "pipeline"]

PIPE_STAGES = 4          # S, E, P, B


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _sequential_core(domain, sp, budget: int, max_nodes: int, rng):
    """Shared S→E→P→B loop; returns (tree, playout values, dup flags)."""
    tree = init_tree(domain, max_nodes or budget + 2)

    def it(tree, rng_t):
        tree, sel = S.select_one(tree, sp, jnp.asarray(True))
        tree, exp = S.expand_one(tree, domain, sp, sel)
        po = S.playout_wave(
            domain, sp,
            jax.tree_util.tree_map(lambda x: x[None], exp), rng_t)
        tree = S.backup_wave(tree, po, sp)
        return tree, (po["value"][0], sel["dup"])

    tree, (values, dups) = jax.lax.scan(
        it, tree, jax.random.split(rng, budget))
    return tree, values, dups


@register_strategy("sequential")
def sequential(domain, cfg: SearchConfig, rng) -> SearchResult:
    tree, values, dups = _sequential_core(domain, cfg.params, cfg.budget,
                                          cfg.max_nodes, rng)
    # one trajectory in flight at a time -> dups.sum() is provably 0, but
    # report the measured event so all strategies share one definition
    stats = make_stats(cfg.budget, cfg.budget, dups.sum(), cfg.budget)
    return result_from_tree(tree, stats, extras={"values": values})


@register_strategy("root")
def root(domain, cfg: SearchConfig, rng) -> SearchResult:
    """Root parallelization / Ensemble UCT (Chaslot; Fern & Lewis):
    ``lanes`` independent sequential searches, root statistics summed.  No
    single shared tree exists, so ``SearchResult.tree`` is None."""
    workers = max(cfg.lanes, 1)
    per = _ceil_div(cfg.budget, workers)

    def one(r):
        tree, _, dups = _sequential_core(domain, cfg.params, per,
                                         cfg.max_nodes, r)
        n, w, _ = root_child_stats(tree)    # n already 0 at invalid slots
        return n.astype(jnp.int32), w, dups.sum()

    ns, ws, dups = jax.vmap(one)(jax.random.split(rng, workers))
    visits, value = ns.sum(0), ws.sum(0)
    best = jnp.argmax(jnp.where(visits > 0, visits, -1)).astype(jnp.int32)
    stats = make_stats(per * workers, per * workers, dups.sum(), per)
    return SearchResult(action_visits=visits, action_value=value,
                        best_action=best, tree=None, stats=stats, extras={})


@register_strategy("leaf")
def leaf(domain, cfg: SearchConfig, rng) -> SearchResult:
    """Leaf parallelization (Chaslot et al.): sequential S/E, ``lanes``
    playouts from the selected leaf per iteration, aggregate backup."""
    sp, workers = cfg.params, max(cfg.lanes, 1)
    iters = _ceil_div(cfg.budget, workers)
    tree = init_tree(domain, cfg.max_nodes or iters + 2)

    def it(tree, rng_t):
        tree, sel = S.select_one(tree, sp, jnp.asarray(True))
        tree, exp = S.expand_one(tree, domain, sp, sel)
        values = jax.vmap(lambda r: domain.playout(exp["state"], r))(
            jax.random.split(rng_t, workers))
        v_sum = values.sum()
        # aggregate backup: n += workers, w += sum(values) along the path;
        # drain whichever in-flight plane Select/Expand incremented
        paths = exp["path"]
        mask = paths >= 0
        idx = jnp.maximum(paths, 0)
        infl = S.infl_plane(tree, sp).at[idx].add(-mask.astype(jnp.int32))
        tree = tree.replace(
            visits=tree.visits.at[idx].add(mask * workers),
            value=tree.value.at[idx].add(jnp.where(mask, v_sum, 0.0)),
            **{("unobs" if sp.wu else "vloss"): infl})
        return tree, sel["dup"]

    tree, dups = jax.lax.scan(it, tree, jax.random.split(rng, iters))
    stats = make_stats(iters * workers, iters * workers, dups.sum(), iters)
    return result_from_tree(tree, stats)


@register_strategy("tree")
def tree_parallel(domain, cfg: SearchConfig, rng) -> SearchResult:
    """Tree parallelization with virtual loss (Chaslot et al.): per round,
    ``lanes`` trajectories selected/expanded/played/backed-up together.
    Staleness grows with lanes — the regime the pipeline bounds."""
    sp, threads = cfg.params, max(cfg.lanes, 1)
    rounds = _ceil_div(cfg.budget, threads)
    tree = init_tree(domain, cfg.max_nodes or rounds * threads + 2)

    fused = sp.resolved_wave_select == "mega"

    def _dup_st(sels):
        return {"dup": sels["dup"].sum(),
                "dup_within": sels["dup_within"].sum(),
                "dup_cross": sels["dup_cross"].sum()}

    def round_fn(tree, rng_t):
        if fused:        # whole round through kernels/search_wave (§14)
            tree, sels = S.mega_round(tree, domain, sp, threads,
                                      jnp.asarray(True), rng_t)
            return tree, _dup_st(sels)
        tree, sels = S.select_wave(tree, sp, threads, jnp.asarray(True))
        tree, exps = S.expand_wave(tree, domain, sp, sels)
        po = S.playout_wave(domain, sp, exps, rng_t)
        tree = S.backup_wave(tree, po, sp)
        return tree, _dup_st(sels)

    tree, st = jax.lax.scan(round_fn, tree, jax.random.split(rng, rounds))
    stats = make_stats(rounds * threads, rounds * threads,
                       st["dup"].sum(), rounds)
    extras = {"dup_within": st["dup_within"].sum(),
              "dup_cross": st["dup_cross"].sum()}
    return result_from_tree(tree, stats, extras)


@register_strategy("pipeline")
def pipeline(domain, cfg: SearchConfig, rng) -> SearchResult:
    """The paper's contribution: software-pipelined MCTS.  One scan tick
    co-schedules  B(wave t-3) | P(wave t-2) | E(wave t-1) | S(wave t),  so
    K = 4 waves are in flight; ``lanes`` parallel playout stages per wave
    (lanes == 1 reproduces the linear pipeline of Fig. 3, lanes > 1 the
    nonlinear pipeline of Fig. 5/6).  See DESIGN.md §2."""
    sp, lanes = cfg.params, max(cfg.lanes, 1)
    n_waves = _ceil_div(cfg.budget, lanes)
    nodes = cfg.max_nodes or (n_waves * lanes + 2)
    tree = init_tree(domain, nodes)
    n_ticks = n_waves + (PIPE_STAGES - 1)       # fill + drain

    init_carry = (
        tree,
        S.empty_selection(sp, lanes),                       # S -> E buffer
        S.empty_expansion(sp, lanes, domain),               # E -> P buffer
        S.empty_playout(sp, lanes, domain.num_actions),     # P -> B buffer
    )

    fused = sp.resolved_wave_select == "mega"

    def tick(carry, inp):
        t, rng_t = inp
        tree, buf_se, buf_ep, buf_pb = carry
        wave_valid = t < n_waves                # Select masked during drain
        if fused:     # one B→E→S launch per tick (kernels/search_wave, §14)
            tree, new_se, new_ep, new_pb = S.mega_tick(
                tree, domain, sp, lanes, wave_valid,
                buf_se, buf_ep, buf_pb, rng_t)
        else:
            # Backup stage — wave t-3 (oldest in flight)
            tree = S.backup_wave(tree, buf_pb, sp)
            # Playout stage — wave t-2 (parallel lanes)
            new_pb = S.playout_wave(domain, sp, buf_ep, rng_t)
            # Expand stage — wave t-1
            tree, new_ep = S.expand_wave(tree, domain, sp, buf_se)
            # Select stage — wave t
            tree, new_se = S.select_wave(tree, sp, lanes, wave_valid)
        st = {
            "dup": new_se["dup"].sum(),
            "dup_within": new_se["dup_within"].sum(),
            "dup_cross": new_se["dup_cross"].sum(),
            "completed": buf_pb["valid"].sum(),
            "occupancy": (new_se["valid"].any().astype(jnp.int32)
                          + buf_se["valid"].any().astype(jnp.int32)
                          + buf_ep["valid"].any().astype(jnp.int32)
                          + buf_pb["valid"].any().astype(jnp.int32)),
        }
        return (tree, new_se, new_ep, new_pb), st

    rngs = jax.random.split(rng, n_ticks)
    ts = jnp.arange(n_ticks)
    (tree, *_), st = jax.lax.scan(tick, init_carry, (ts, rngs))
    stats = make_stats(n_waves * lanes, st["completed"].sum(),
                       st["dup"].sum(), n_ticks)
    extras = {
        "mean_occupancy": st["occupancy"].mean() / PIPE_STAGES,
        "dup_per_tick": st["dup"],
        "dup_within": st["dup_within"].sum(),
        "dup_cross": st["dup_cross"].sum(),
    }
    return result_from_tree(tree, stats, extras)
