"""The unified public search API (DESIGN.md §3–§5).

One entry point for every parallelization pattern in the paper:

    from repro.search import SearchConfig, search, search_batch

    res = search(domain, SearchConfig(method="pipeline", budget=256,
                                      lanes=8), jax.random.key(0))
    res.best_action          # recommended root action (robust child)
    res.action_visits        # [A] root child visit counts
    res.stats                # common schema, identical keys for all methods

Strategies are looked up in a string-keyed registry so new parallelizations
plug in without touching callers:

    @register_strategy("my_method")
    def _my_method(domain, cfg, rng) -> SearchResult: ...

``search_batch`` vmaps B independent searches into ONE device program
(batched multi-root search) — the scaling primitive that lets serving run a
whole batch of decode requests per device call.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stages import SearchParams
from repro.core.tree import Tree, root_child_stats
from repro.search.domain import Domain, missing_members

__all__ = [
    "STATS_KEYS", "SearchConfig", "SearchResult", "StrategyFn",
    "register_strategy", "get_strategy", "list_strategies",
    "make_stats", "result_from_tree", "search", "search_batch",
]

# Every strategy returns exactly this stats key set (ISSUE: "identical
# across all five").  ``playouts`` is the headline number and always equals
# ``playouts_completed``; ``playouts_requested`` is the nominal budget after
# lane/worker rounding (the two differ only transiently, e.g. a capped tree).
STATS_KEYS = ("playouts", "playouts_requested", "playouts_completed",
              "duplicates", "ticks")

StrategyFn = Callable[..., "SearchResult"]

_STRATEGIES: Dict[str, StrategyFn] = {}


class SearchResult(NamedTuple):
    """Standardized result pytree — identical field set for every strategy.

    ``tree`` is the full search tree for single-tree strategies, ``None`` for
    root parallelization (workers' trees are merged into the root stats) or
    when ``SearchConfig.keep_tree`` is False.  ``stats`` always carries
    exactly ``STATS_KEYS`` (int32 scalars); ``extras`` holds per-strategy
    diagnostics (e.g. the pipeline's ``mean_occupancy``) and may differ
    between strategies.
    """

    action_visits: jnp.ndarray          # [A] i32 root child visit counts
    action_value: jnp.ndarray           # [A] f32 root child reward sums
    best_action: jnp.ndarray            # scalar i32 (robust child)
    tree: Optional[Tree]                # full tree, or None
    stats: Dict[str, jnp.ndarray]       # common schema: STATS_KEYS
    extras: Dict[str, Any]              # strategy-specific diagnostics


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """One config for all strategies.

    method:    registry key — "sequential" | "root" | "leaf" | "tree"
               | "pipeline" (see ``list_strategies()``).
    budget:    total playouts.  Strategies with ``lanes`` > 1 round up to a
               whole number of waves/rounds; ``stats["playouts_requested"]``
               records the rounded value.
    lanes:     degree of parallelism.  Unifies the old per-runner names:
               pipeline lanes == tree-parallel threads == root/leaf workers.
               Ignored by "sequential".
    max_nodes: tree capacity (0 -> strategy default, sized to the budget).
    keep_tree: when False, ``SearchResult.tree`` is dropped (saves memory in
               ``search_batch`` fan-outs).
    params:    the shared UCT/virtual-loss knobs (core.stages.SearchParams).
    kernels /
    wave_select /
    vl_mode /
    level_assign: top-level conveniences for the consolidated kernel pair,
               the in-flight-statistics mode, and the within-level lane
               assignment (DESIGN.md §14/§15/§16).  Anything other than the
               default is forwarded into ``params`` at construction, so
               ``SearchConfig(kernels="pallas")`` ==
               ``SearchConfig(params=SearchParams(kernels="pallas"))``.
               ``vl_mode``: "loss" (virtual loss, the unchanged default) or
               "wu" (WU-UCT unobserved counts — Q from completed stats only).
               ``level_assign``: "independent" (default) or "running" (the
               within-level running-assignment scan — co-located lockstep
               lanes spread instead of stacking).
    """

    method: str = "sequential"
    budget: int = 256
    lanes: int = 1
    max_nodes: int = 0
    keep_tree: bool = True
    params: SearchParams = dataclasses.field(default_factory=SearchParams)
    kernels: str = "auto"
    wave_select: str = "auto"
    vl_mode: str = "loss"
    level_assign: str = "independent"

    def __post_init__(self):
        upd = {}
        if self.kernels != "auto" and self.params.kernels == "auto":
            upd["kernels"] = self.kernels
        if self.wave_select != "auto" and self.params.wave_select == "auto":
            upd["wave_select"] = self.wave_select
        if self.vl_mode != "loss" and self.params.vl_mode == "loss":
            upd["vl_mode"] = self.vl_mode
        if self.level_assign != "independent" \
                and self.params.level_assign == "independent":
            upd["level_assign"] = self.level_assign
        if upd:
            object.__setattr__(
                self, "params", dataclasses.replace(self.params, **upd))


# ---------------------------------------------------------------------------
# strategy registry
# ---------------------------------------------------------------------------
def register_strategy(name: str) -> Callable[[StrategyFn], StrategyFn]:
    """Decorator: register ``fn(domain, cfg, rng) -> SearchResult`` under
    ``name``.  Re-registering a name overwrites it (supports reloads)."""
    def deco(fn: StrategyFn) -> StrategyFn:
        _STRATEGIES[name] = fn
        return fn
    return deco


def get_strategy(name: str) -> StrategyFn:
    _ensure_builtin_strategies()
    try:
        return _STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown search method {name!r}; "
            f"registered: {list_strategies()}") from None


def list_strategies() -> List[str]:
    _ensure_builtin_strategies()
    return sorted(_STRATEGIES)


def _ensure_builtin_strategies() -> None:
    # Imported lazily: strategies.py imports this module for the decorator.
    from repro.search import strategies  # noqa: F401


# ---------------------------------------------------------------------------
# result assembly helper (used by strategies.py)
# ---------------------------------------------------------------------------
def make_stats(requested, completed, duplicates, ticks) -> Dict[str, jnp.ndarray]:
    completed = jnp.asarray(completed, jnp.int32)
    return {
        "playouts": completed,
        "playouts_requested": jnp.asarray(requested, jnp.int32),
        "playouts_completed": completed,
        "duplicates": jnp.asarray(duplicates, jnp.int32),
        "ticks": jnp.asarray(ticks, jnp.int32),
    }


def result_from_tree(tree: Tree, stats: Dict[str, jnp.ndarray],
                     extras: Optional[Dict[str, Any]] = None) -> SearchResult:
    n, w, valid = root_child_stats(tree)
    best = jnp.argmax(jnp.where(valid, n, -1)).astype(jnp.int32)
    return SearchResult(action_visits=n.astype(jnp.int32), action_value=w,
                        best_action=best, tree=tree, stats=stats,
                        extras=extras or {})


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def search(domain, cfg: SearchConfig, rng) -> SearchResult:
    """Run one search.  Pure and jit/vmap-compatible: strategies are built
    from lax control flow, so ``jax.jit(lambda r: search(dom, cfg, r))``
    compiles to a single device program."""
    if not isinstance(domain, Domain):
        raise TypeError(
            f"{type(domain).__name__} does not satisfy the Domain protocol "
            f"(missing {missing_members(domain)}); see repro.search.domain")
    res = get_strategy(cfg.method)(domain, cfg, rng)
    missing = set(STATS_KEYS) ^ set(res.stats)
    if missing:
        raise RuntimeError(
            f"strategy {cfg.method!r} broke the common stats schema "
            f"(symmetric difference: {sorted(missing)})")
    if not cfg.keep_tree:
        res = res._replace(tree=None)
    return res


def search_batch(domains: Sequence[Any], cfg: SearchConfig, rng,
                 *, mesh=None) -> SearchResult:
    """Batched multi-root search: B independent searches in ONE XLA program.

    ``domains`` is a sequence of B domain instances of the same type.  Fields
    that differ between instances (e.g. each request's prompt) must be
    array-valued; they are stacked and vmapped over.  Fields that are shared
    (model params, static config) stay closed over once.

    RNG contract: ``rng`` is split into B keys, so
    ``search_batch(domains, cfg, rng).action_visits[i]`` equals
    ``search(domains[i], cfg, jax.random.split(rng, B)[i]).action_visits``.

    Multi-device: pass ``mesh`` (a 1-D device mesh) to shard the batch axis
    across devices, or rely on auto-sharding — when more than one device is
    visible and the call is not inside a trace, the batch is sharded over a
    default all-device mesh.  Per-root results are identical either way
    (DESIGN.md §9); pass ``mesh=False`` to force the single-device vmap.

    Returns a ``SearchResult`` whose every leaf gains a leading batch axis.
    """
    domains = list(domains)
    if not domains:
        raise ValueError("search_batch needs at least one domain")
    # auto-shard only when there is real batch parallelism to split: at B=1
    # padding to the mesh would run device_count searches to keep one
    if mesh is None and len(domains) > 1 and jax.device_count() > 1 \
            and not _contains_tracer(rng, *domains):
        from repro.launch.mesh import make_search_mesh
        mesh = make_search_mesh()
    if mesh is not None and mesh is not False:
        from repro.search.sharding import shard_search_batch
        return shard_search_batch(domains, cfg, rng, mesh=mesh)
    rngs = jax.random.split(rng, len(domains))
    make, batched = _batch_domains(domains)
    if batched is None:
        return jax.vmap(lambda r: search(domains[0], cfg, r))(rngs)
    return jax.vmap(lambda bat, r: search(make(bat), cfg, r))(batched, rngs)


def _contains_tracer(*objs) -> bool:
    """True when any value (or dataclass field / pytree leaf thereof) is a
    jax tracer — i.e. the caller is already inside jit/vmap, where device
    placement is owned by the enclosing program, not by auto-sharding."""
    for o in objs:
        vals = ([getattr(o, f.name) for f in dataclasses.fields(o)]
                if dataclasses.is_dataclass(o) and not isinstance(o, type)
                else [o])
        for v in vals:
            if any(isinstance(leaf, jax.core.Tracer)
                   for leaf in jax.tree_util.tree_leaves(v)):
                return True
    return False


def _static_eq(a, b) -> bool:
    """True when two field values are interchangeable as static config."""
    if a is b:
        return True
    if isinstance(a, (int, float, str, bool, bytes, type(None))):
        return type(a) is type(b) and a == b
    if dataclasses.is_dataclass(a) and type(a) is type(b):
        try:
            return bool(a == b)       # equal-valued configs built separately
        except Exception:  # noqa: BLE001 — array fields make == ambiguous
            return False
    # pytrees of concrete arrays (e.g. the same model params built twice):
    # equal values are shared static config — without this, search_batch
    # would silently stack B copies of the weights
    try:
        if (jax.tree_util.tree_structure(a)
                != jax.tree_util.tree_structure(b)):
            return False
        la = jax.tree_util.tree_leaves(a)
        lb = jax.tree_util.tree_leaves(b)
        if any(isinstance(x, jax.core.Tracer) for x in la + lb):
            return False              # traced values genuinely vary
        return all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(la, lb))
    except Exception:  # noqa: BLE001 — non-array leaves etc.
        return False


def _batch_domains(domains):
    """Split a list of same-typed domains into (rebuild_fn, stacked_fields).

    Returns (None, None) when every instance is identical — the caller then
    vmaps over rng only.  Otherwise each differing dataclass field is stacked
    leaf-wise into a leading batch axis and ``rebuild_fn`` reconstructs one
    domain from one batch slice via ``dataclasses.replace``.
    """
    d0 = domains[0]
    if all(d is d0 for d in domains[1:]):
        return None, None
    if any(type(d) is not type(d0) for d in domains[1:]):
        raise TypeError("search_batch domains must all share one type; got "
                        f"{sorted({type(d).__name__ for d in domains})}")
    if not dataclasses.is_dataclass(d0):
        raise TypeError(
            f"search_batch over distinct {type(d0).__name__} instances "
            "requires a dataclass domain (so differing fields can be "
            "stacked); pass identical instances or make it a dataclass")
    varying = {}
    for f in dataclasses.fields(d0):
        vals = [getattr(d, f.name) for d in domains]
        if all(_static_eq(v, vals[0]) for v in vals[1:]):
            continue
        if any(v is None or isinstance(v, (int, str, bytes)) for v in vals):
            # ints are shape-determining (num_actions, depths, seeds) — a
            # tracer there crashes deep inside the strategy; fail clearly
            raise TypeError(
                f"search_batch domains disagree on field {f.name!r} "
                f"({[getattr(d, f.name) for d in domains]!r}); static "
                "Python fields must be equal across the batch — only "
                "array-valued (or float) fields may vary")
        try:
            varying[f.name] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *vals)
        except Exception as e:  # noqa: BLE001 — re-raise with field context
            raise TypeError(
                f"search_batch cannot batch field {f.name!r} of "
                f"{type(d0).__name__}: values differ but are not stackable "
                f"arrays ({e})") from e
    if not varying:
        return None, None

    def make(bat):
        return dataclasses.replace(d0, **bat)

    return make, varying
