"""The formal Domain contract for all search strategies (DESIGN.md §3).

A *domain* is any object exposing the game/decision-process interface the
MCTS stages consume.  The seed repo relied on duck typing; this module makes
the contract explicit and checkable:

* ``Domain`` — a ``runtime_checkable`` Protocol.  ``isinstance(obj, Domain)``
  verifies the required attributes exist (structural check only).
* ``SupportsPriors`` — the optional extension supplying per-action priors
  (PUCT); strategies fall back to uniform priors when absent.
* ``check_domain(domain)`` — an adapter check that abstract-evaluates the
  domain's methods (via ``jax.eval_shape``, no real compute) and raises
  ``TypeError`` listing every contract violation.

Required members
----------------
``num_actions : int``
    Static branching factor A (> 0).
``root_state() -> pytree``
    The search root's domain state.  Leaves must be fixed-shape arrays so
    states can live in the structure-of-arrays tree (core.tree) and be
    batched by vmap.
``step(state, action) -> state``
    Apply an int32 action; must preserve the state pytree structure,
    shapes and dtypes (scan/vmap requirement).
``is_terminal(state) -> bool scalar``
``playout(state, rng) -> float scalar``
    Monte-Carlo evaluation of ``state``; reward convention is [0, 1].

Optional members
----------------
``priors(state) -> [num_actions] float array``
    Action priors for PUCT selection.
"""
from __future__ import annotations

from typing import Any, List, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

__all__ = ["Domain", "SupportsPriors", "check_domain", "missing_members"]


@runtime_checkable
class Domain(Protocol):
    """Structural type every search strategy accepts (see module docstring)."""

    num_actions: int

    def root_state(self) -> Any: ...

    def step(self, state: Any, action: Any) -> Any: ...

    def is_terminal(self, state: Any) -> Any: ...

    def playout(self, state: Any, rng: Any) -> Any: ...


@runtime_checkable
class SupportsPriors(Protocol):
    """Optional extension: domains that provide PUCT priors."""

    def priors(self, state: Any) -> Any: ...


def _describe(x) -> str:
    return jax.tree_util.tree_structure(x).__repr__()


def missing_members(domain: Any) -> List[str]:
    """Required Domain members ``domain`` lacks (empty = structurally OK)."""
    return [m for m in ("num_actions", "root_state", "step",
                        "is_terminal", "playout")
            if not hasattr(domain, m)]


def check_domain(domain: Any) -> bool:
    """Validate ``domain`` against the Domain contract; raise TypeError on
    violations.  Uses abstract evaluation only — safe for expensive domains.
    """
    problems: List[str] = []
    if not isinstance(domain, Domain):
        raise TypeError(f"{type(domain).__name__} is not a Domain: "
                        f"missing {missing_members(domain)}")

    a = domain.num_actions
    if not isinstance(a, int) or a <= 0:
        problems.append(f"num_actions must be a positive int, got {a!r}")

    try:
        s0 = jax.eval_shape(domain.root_state)
        s0_shapes = s0
    except Exception as e:  # noqa: BLE001 — collect into the report
        raise TypeError(f"root_state() failed abstract eval: {e}") from e

    def same_struct(x, y):
        return (jax.tree_util.tree_structure(x) == jax.tree_util.tree_structure(y)
                and all(ax.shape == ay.shape and ax.dtype == ay.dtype
                        for ax, ay in zip(jax.tree_util.tree_leaves(x),
                                          jax.tree_util.tree_leaves(y))))

    try:
        s1 = jax.eval_shape(lambda s: domain.step(s, jnp.int32(0)), s0)
        if not same_struct(s1, s0_shapes):
            problems.append(
                "step() must preserve the state pytree "
                f"(got {_describe(s1)}, want {_describe(s0_shapes)})")
    except Exception as e:  # noqa: BLE001
        problems.append(f"step() failed abstract eval: {e}")

    try:
        t = jax.eval_shape(domain.is_terminal, s0)
        if jnp.shape(t) != () or t.dtype != jnp.bool_:
            problems.append(
                f"is_terminal() must return a bool scalar, got "
                f"shape={jnp.shape(t)} dtype={t.dtype}")
    except Exception as e:  # noqa: BLE001
        problems.append(f"is_terminal() failed abstract eval: {e}")

    try:
        v = jax.eval_shape(domain.playout, s0, jax.random.key(0))
        if jnp.shape(v) != ():
            problems.append(
                f"playout() must return a scalar, got shape={jnp.shape(v)}")
    except Exception as e:  # noqa: BLE001
        problems.append(f"playout() failed abstract eval: {e}")

    if isinstance(domain, SupportsPriors):
        try:
            p = jax.eval_shape(domain.priors, s0)
            if jnp.shape(p) != (a,):
                problems.append(
                    f"priors() must return shape ({a},), got {jnp.shape(p)}")
        except Exception as e:  # noqa: BLE001
            problems.append(f"priors() failed abstract eval: {e}")

    if problems:
        raise TypeError(f"{type(domain).__name__} violates the Domain "
                        "contract:\n  - " + "\n  - ".join(problems))
    return True
