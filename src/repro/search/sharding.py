"""Batch-axis sharding for batched multi-root search (DESIGN.md §9, §13).

``search_batch`` runs B independent searches as one vmapped XLA program on a
single device.  ``shard_search_batch`` runs the *same* program partitioned
over a 1-D device mesh: the stacked-domain pytree and the per-root rng keys
are sharded along the batch axis with ``jit`` + ``NamedSharding``, so each
device executes B/ndev roots of an identical per-root computation — the
array-decomposed analogue of root parallelism on "large parallel machines"
(the regime the paper targets).

The mesh may span multiple processes (``jax.distributed``-initialized
multi-host jobs): inputs are then placed with
``compat.global_batch_put`` (every process holds the same host value and
contributes its addressable shards — no cross-process input transfer, which
is sound because the inputs are deterministic functions of arguments every
process passes identically), the per-root programs still run without any
cross-device communication, and the results are all-gathered back to every
process with ``compat.replicate_to_hosts`` so each host returns the full
``SearchResult``.

Contracts (tested in tests/test_sharding.py and tests/test_multihost.py):

* **Per-root semantics are identical** to ``search_batch``: the rng is split
  into exactly B keys *before* any padding or placement, and every batch
  element i reproduces ``search(domains[i], cfg, jax.random.split(rng, B)[i])``
  bit-for-bit on ``action_visits``/``stats`` — on one device, on a
  single-process mesh, and on a multi-host mesh.
* **Padding**: B is padded up to a multiple of the mesh's device count by
  repeating row 0 (a valid domain + key); padded rows run a real search
  whose outputs are sliced off before returning.
* **Version compat**: meshes and shardings are built through
  ``repro.parallel.compat`` (jax 0.4.37 and current jax).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.compat import (batch_sharding, global_batch_put,
                                   mesh_is_multihost, mesh_num_devices,
                                   replicate_to_hosts)

__all__ = ["shard_search_batch"]


def _default_mesh():
    from repro.launch.mesh import make_search_mesh
    return make_search_mesh()


def _pad_rows(x, pad: int):
    """Append ``pad`` copies of row 0 (works for typed prng key arrays too —
    jnp.broadcast_to/concatenate dispatch on the extended dtype)."""
    if pad == 0:
        return x
    fill = jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])
    return jnp.concatenate([x, fill], axis=0)


def shard_search_batch(domains, cfg, rng, *, mesh=None):
    """``search_batch`` with the batch axis sharded over a device mesh.

    ``mesh`` is a 1-D mesh (default: ``repro.launch.mesh.make_search_mesh()``
    over every visible device — *global* devices in a multi-host job).
    Returns the same ``SearchResult`` pytree as
    ``search_batch(domains, cfg, rng)`` — same leading batch axis B, same
    per-root values — with every leaf sharded along the mesh's batch axis
    (re-replicated to every process first when the mesh is multi-host).
    """
    domains = list(domains)
    if not domains:
        raise ValueError("shard_search_batch needs at least one domain")
    # rng contract: split into exactly B keys BEFORE padding or placement, so
    # element i matches search(domains[i], cfg, jax.random.split(rng, B)[i])
    rngs = jax.random.split(rng, len(domains))
    return shard_search_keys(domains, cfg, rngs, mesh=mesh)


def shard_search_keys(domains, cfg, keys, *, mesh=None):
    """``shard_search_batch`` with the per-root keys already split out.

    The elastic driver (search/ft.py) re-runs arbitrary subsets of roots
    under their ORIGINAL keys; this is the shared implementation that makes
    a requeued root bit-for-bit identical to its uninterrupted run.
    """
    from repro.search.api import _batch_domains, search

    domains = list(domains)
    if mesh is None:
        mesh = _default_mesh()
    ndev = mesh_num_devices(mesh)
    b = len(domains)
    pad = (-b) % ndev
    make, batched = _batch_domains(domains)

    sharded = batch_sharding(mesh)
    multihost = mesh_is_multihost(mesh)
    rngs = global_batch_put(_pad_rows(keys, pad), sharded)
    if batched is None:
        d0 = domains[0]
        fn = jax.jit(jax.vmap(lambda r: search(d0, cfg, r)),
                     out_shardings=sharded)
        res = fn(rngs)
    else:
        batched = jax.tree_util.tree_map(
            lambda x: global_batch_put(_pad_rows(x, pad), sharded), batched)
        fn = jax.jit(jax.vmap(lambda bat, r: search(make(bat), cfg, r)),
                     out_shardings=sharded)
        res = fn(batched, rngs)
    if multihost:
        res = replicate_to_hosts(res, mesh)
    if pad:
        res = jax.tree_util.tree_map(lambda x: x[:b], res)
    return res
