"""Paper Figs. 3/4/6: pipeline scheduling makespans + steady-state throughput.

Columns: name, value, derived (expected-from-paper where applicable).
"""
from __future__ import annotations

import time

from repro.core import schedule


def run(report):
    cases = [
        ("fig3_linear_equal_4traj", (4, (1, 1, 1, 1), 1), 7.0),
        ("fig4_unequal_playout2x_4traj", (4, (1, 1, 2, 1), 1), 11.0),
        ("fig6_nonlinear_2lanes_4traj", (4, (1, 1, 2, 1), 2), 8.0),
        ("sequential_4traj", None, 16.0),
    ]
    for name, args, expected in cases:
        t0 = time.perf_counter()
        if args is None:
            val = schedule.sequential_makespan(4)
        else:
            val = schedule.pipeline_makespan(*args)
        us = (time.perf_counter() - t0) * 1e6
        report(name, us, f"makespan={val}T expected={expected}T "
                         f"match={abs(val - expected) < 1e-9}")
    # steady-state throughput scaling with lanes (paper §V-C)
    for lanes in (1, 2, 4, 8):
        thr = schedule.steady_state_throughput((1, 1, 4, 1), lanes)
        report(f"steady_state_throughput_lanes{lanes}", 0.0,
               f"traj_per_T={thr:.3f} (playout=4T)")
    # occupancy fill/drain trace summary
    grid, busy = schedule.occupancy_trace(16, (1, 1, 2, 1), lanes=2)
    full = busy.max()
    frac = (busy >= full * 0.99).mean()
    report("occupancy_16traj_2lanes", 0.0,
           f"peak_busy_PEs={full:.0f} frac_time_at_peak={frac:.2f}")
