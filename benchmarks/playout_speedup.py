"""Playout-speedup (paper §II def. 1): wall-clock playouts/s of the batched
pipeline vs the sequential baseline on the P-game domain, sweeping lanes;
plus batched multi-root scaling (``search_batch``: B independent searches in
one device program, the serving fan-out primitive).

On CPU the parallel playout stage vectorizes across lanes (the TPU analogue
is data-axis sharding), so playouts/s growing with lanes is the real,
measured counterpart of the schedule model's prediction.
"""
from __future__ import annotations

import time

import jax

from repro.core.domains.pgame import PGameDomain
from repro.search import SearchConfig, SearchParams, search, search_batch

DOM = PGameDomain(num_actions=4, game_depth=8, binary_reward=False, seed=1)
SP = SearchParams(cp=0.7, max_depth=8)
BUDGET = 512


def _time(f, *args, reps=3):
    f(*args)                                   # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def run(report, smoke: bool = False):
    budget = 32 if smoke else BUDGET
    reps = 1 if smoke else 3
    seq_cfg = SearchConfig(method="sequential", budget=budget, params=SP,
                           keep_tree=False)
    seq = jax.jit(lambda r: search(DOM, seq_cfg, r).action_visits)
    t_seq = _time(seq, jax.random.key(0), reps=reps)
    report(f"sequential_{budget}playouts", t_seq * 1e6,
           f"playouts_per_s={budget / t_seq:,.0f}")
    for lanes in ((1, 4) if smoke else (1, 2, 4, 8, 16)):
        cfg = SearchConfig(method="pipeline", budget=budget, lanes=lanes,
                           params=SP, keep_tree=False)
        pipe = jax.jit(lambda r: search(DOM, cfg, r).action_visits)
        t = _time(pipe, jax.random.key(0), reps=reps)
        report(f"pipeline_lanes{lanes}_{budget}playouts", t * 1e6,
               f"playouts_per_s={budget / t:,.0f} speedup_vs_seq={t_seq / t:.2f}x")

    # batched multi-root: B independent pipelines in one XLA program
    cfg = SearchConfig(method="pipeline", budget=budget, lanes=8, params=SP,
                       keep_tree=False)
    for b in ((1, 4) if smoke else (1, 4, 16)):
        fn = jax.jit(lambda r: search_batch([DOM] * b, cfg, r).action_visits)
        t = _time(fn, jax.random.key(0), reps=reps)
        report(f"search_batch_B{b}_{budget}playouts", t * 1e6,
               f"total_playouts_per_s={b * budget / t:,.0f}")
