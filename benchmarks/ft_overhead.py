"""Elastic-driver overhead and failure cost (DESIGN.md §13).

At zero failures ``ft_search_batch`` (one logical host owning the whole
mesh) runs the exact same sharded program as ``shard_search_batch`` plus the
driver's bookkeeping (key pre-split, queue management, host-side commit of
the result accumulator) — the ``ft_driver`` row's overhead ratio is gated at
<=1.05x in CI.  The ``ft_driver_kill`` row measures a run that loses a host
mid-flight: the paper's failure model prices a loss in lost playouts, and
the derived column reports exactly that (requeued roots x budget).

Both sides are timed end-to-end to host numpy (the driver commits to host
as part of its contract, so the baseline must pay the same transfer).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.domains.pgame import PGameDomain
from repro.launch.mesh import make_search_mesh
from repro.search import (ElasticSearchDriver, FTSearchConfig, SearchConfig,
                          SearchParams, shard_search_batch)

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=2)
SP = SearchParams(cp=0.7, max_depth=6)


def _to_host(res):
    return jax.tree_util.tree_map(np.asarray, res)


def _time(f, reps: int) -> float:
    f()                                    # warm libraries / first dispatch
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        f()
        best = min(best, time.perf_counter() - t0)
    return best


def run(report, smoke: bool = False):
    b = 4 if smoke else 8
    budget = 32 if smoke else 128
    reps = 2 if smoke else 3
    cfg = SearchConfig(method="pipeline", budget=budget, lanes=4, params=SP,
                      keep_tree=False)
    doms = [DOM] * b
    rng = jax.random.key(0)
    mesh = make_search_mesh()

    def plain():
        return _to_host(shard_search_batch(doms, cfg, rng, mesh=mesh))

    def ft_zero_failures():
        drv = ElasticSearchDriver(doms, cfg, rng,
                                  FTSearchConfig(hosts=1, chunk=0), mesh=mesh)
        return drv.run()

    t_plain = _time(plain, reps)
    t_ft = _time(ft_zero_failures, reps)
    ratio = t_ft / t_plain
    report(f"ft_plain_B{b}", t_plain * 1e6,
           f"total_playouts_per_s={b * budget / t_plain:,.0f}")
    report(f"ft_driver_B{b}", t_ft * 1e6,
           f"overhead_vs_plain={ratio:.3f}x (CI gate <=1.05x, zero failures)")

    # merge contract sanity while both results are in hand
    base = plain()
    out = ft_zero_failures()
    np.testing.assert_array_equal(base.action_visits, out.action_visits)

    # failure cost: lose one of two hosts the moment it launches its chunk;
    # the run completes, paying only the victim's in-flight playouts again
    def ft_kill():
        drv = ElasticSearchDriver(
            doms, cfg, rng,
            FTSearchConfig(hosts=2, chunk=b // 2, watchdog_s=30.0,
                           kill_host_at_root=b - 1), mesh=mesh)
        res = drv.run()
        return drv, res

    drv, res = ft_kill()
    np.testing.assert_array_equal(base.action_visits, res.action_visits)
    t_kill = _time(lambda: ft_kill(), 1)
    lost = len(drv.report.requeued)
    report(f"ft_driver_kill_B{b}", t_kill * 1e6,
           f"requeued_roots={lost} lost_playouts={lost * budget} "
           f"recovery_vs_plain={t_kill / t_plain:.2f}x")
