"""Straggler mitigation: throughput vs drop-rate for the playout-lane
deadline policy under heavy-tailed lane latencies (runtime/straggler.py)."""
from __future__ import annotations

import time

from repro.runtime.straggler import StragglerPolicy, simulate_throughput


def run(report, smoke: bool = False):
    waves = 40 if smoke else 400
    for df in ((2.0, 1e9) if smoke else (2.0, 3.0, 5.0, 1e9)):
        t0 = time.perf_counter()
        out = simulate_throughput(StragglerPolicy(deadline_factor=df),
                                  lanes=32, waves=waves, tail=0.12)
        us = (time.perf_counter() - t0) * 1e6
        tag = "no_deadline" if df > 1e6 else f"deadline_{df}x"
        report(f"straggler_{tag}", us,
               f"speedup={out['speedup']:.2f}x drop_rate={out['drop_rate']:.3f} "
               f"throughput={out['throughput']:.2f}/T")
