"""Multi-device scaling of batched multi-root search (DESIGN.md §9):
total and per-device playouts/s of the batch axis sharded over a 1-D mesh
vs the single-device vmap baseline, at B = 4 roots per device.

With one visible device (the default environment) the measurement
re-launches itself in a subprocess with 8 forced host CPU devices, exactly
like tests/test_distributed.py.
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys
import time

import jax

from repro.core.domains.pgame import PGameDomain
from repro.search import SearchConfig, SearchParams, search

DOM = PGameDomain(num_actions=4, game_depth=8, binary_reward=False, seed=1)
SP = SearchParams(cp=0.7, max_depth=8)


def _time(f, *args, reps=3):
    f(*args)                                   # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def _measure(report, smoke: bool):
    from repro.launch.mesh import make_search_mesh
    from repro.parallel.compat import batch_sharding

    ndev = jax.device_count()
    budget = 32 if smoke else 256
    per_dev = 1 if smoke else 4
    b = per_dev * ndev
    cfg = SearchConfig(method="pipeline", budget=budget, lanes=8, params=SP,
                       keep_tree=False)
    rngs = jax.random.split(jax.random.key(0), b)
    body = jax.vmap(lambda r: search(DOM, cfg, r).action_visits)

    # baseline: the whole batch vmapped on one device (uncommitted inputs)
    t_base = _time(jax.jit(body), rngs, reps=1 if smoke else 3)
    report(f"vmap_1dev_B{b}", t_base * 1e6,
           f"total_playouts_per_s={b * budget / t_base:,.0f}")

    sharded = batch_sharding(make_search_mesh())
    rngs_s = jax.device_put(rngs, sharded)
    t_shard = _time(jax.jit(body, out_shardings=sharded), rngs_s,
                    reps=1 if smoke else 3)
    report(f"sharded_{ndev}dev_B{b}", t_shard * 1e6,
           f"total_playouts_per_s={b * budget / t_shard:,.0f} "
           f"per_dev={b * budget / t_shard / ndev:,.0f} "
           f"speedup_vs_1dev={t_base / t_shard:.2f}x")

    # the shipped API end-to-end (shard_search_batch: trace + device_put +
    # pad/unpad every call) — tracks regressions the steady-state rows above
    # can't see
    from repro.search import shard_search_batch
    doms = [DOM] * b
    key = jax.random.key(0)
    jax.block_until_ready(
        shard_search_batch(doms, cfg, key).action_visits)     # warm libraries
    t0 = time.perf_counter()
    jax.block_until_ready(
        shard_search_batch(doms, cfg, key).action_visits)
    t_api = time.perf_counter() - t0
    report(f"shard_search_batch_api_B{b}", t_api * 1e6,
           f"total_playouts_per_s={b * budget / t_api:,.0f} "
           f"(includes per-call retrace)")


def run(report, smoke: bool = False):
    if jax.device_count() > 1:
        _measure(report, smoke)
        return
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=f"{root / 'src'}:{root}")
    cmd = [sys.executable, "-m", "benchmarks.run", "--only", "shard_scaling"]
    if smoke:
        cmd.append("--smoke")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=1200,
                       cwd=root, env=env)
    if r.returncode != 0:
        raise RuntimeError(
            f"8-device subprocess failed:\n{r.stdout[-2000:]}{r.stderr[-2000:]}")
    for line in r.stdout.splitlines():
        parts = line.split(",", 2)
        if len(parts) == 3 and parts[1] not in ("us_per_call",):
            try:
                us = float(parts[1])
            except ValueError:
                continue
            report(parts[0], us, parts[2])
