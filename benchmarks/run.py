"""Benchmark harness — one module per paper artifact (see DESIGN.md §7).

Prints ``name,us_per_call,derived`` CSV.  Usage:
  PYTHONPATH=src python -m benchmarks.run [--only <module>] [--smoke]
                                          [--json PATH]

``--smoke`` runs every module at tiny budgets (CI perf-trajectory mode);
``--json PATH`` additionally writes the rows as a JSON list of
``{bench, name, us_per_call, derived}`` objects (the CI artifact
``BENCH_pr.json``).
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import json
import sys
import traceback

MODULES = [
    "pipeline_schedule",     # Figs 3/4/6 + steady-state throughput
    "playout_speedup",       # §II def. 1
    "strength_speedup",      # §II def. 2 + §IV baselines
    "search_overhead",       # §III-B
    "strength_bench",        # wu vs vloss at equal wall-clock (DESIGN §15)
    "mcts_decode_bench",     # modern instantiation (NN playouts)
    "serving_bench",         # request lifecycle: cold vs KV-splice+reuse
    "shard_scaling",         # batch axis over a device mesh (DESIGN.md §9)
    "ft_overhead",           # elastic driver at zero failures (DESIGN.md §13)
    "straggler_bench",       # runtime policy
    "kernel_bench",          # per-kernel micro numbers
    "ablations",             # vl-weight / in-flight / MoE-capacity knobs
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny budgets: exercise every module, fast")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON to PATH")
    args = ap.parse_args()
    mods = [args.only] if args.only else MODULES
    print("name,us_per_call,derived")

    rows = []
    current = [""]

    def report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()
        rows.append({"bench": current[0], "name": name,
                     "us_per_call": round(float(us), 1), "derived": derived})

    failed = []
    for m in mods:
        current[0] = m
        try:
            mod = importlib.import_module(f"benchmarks.{m}")
            if "smoke" in inspect.signature(mod.run).parameters:
                mod.run(report, smoke=args.smoke)
            else:
                mod.run(report)
        except Exception as e:
            failed.append(m)
            print(f"{m},-1,ERROR {type(e).__name__}: {e}")
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}", file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
