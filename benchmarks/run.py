"""Benchmark harness — one module per paper artifact (see DESIGN.md §7).

Prints ``name,us_per_call,derived`` CSV.  Usage:
  PYTHONPATH=src python -m benchmarks.run [--only <module>]
"""
from __future__ import annotations

import argparse
import importlib
import sys
import traceback

MODULES = [
    "pipeline_schedule",     # Figs 3/4/6 + steady-state throughput
    "playout_speedup",       # §II def. 1
    "strength_speedup",      # §II def. 2 + §IV baselines
    "search_overhead",       # §III-B
    "mcts_decode_bench",     # modern instantiation (NN playouts)
    "straggler_bench",       # runtime policy
    "kernel_bench",          # per-kernel micro numbers
    "ablations",             # vl-weight / in-flight / MoE-capacity knobs
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    mods = [args.only] if args.only else MODULES
    print("name,us_per_call,derived")

    def report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    failed = []
    for m in mods:
        try:
            mod = importlib.import_module(f"benchmarks.{m}")
            mod.run(report)
        except Exception as e:
            failed.append(m)
            print(f"{m},-1,ERROR {type(e).__name__}: {e}")
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
