"""Search overhead (paper §III-B): budget_parallel / budget_sequential to
reach a target strength, from strength-vs-budget curves; plus the direct
in-flight duplicate-rate signal vs concurrency.  All strategies go through
the unified ``repro.search`` API.

Also home of the lockstep-vs-scan Select rows (DESIGN.md §11):

* ``select_wave_{scan,lockstep}_lanesL`` — Select-stage throughput in
  isolation (one wave = L trajectory selections on a grown tree; the
  lockstep row's ``derived`` carries the speedup CI asserts on).
* ``select_e2e_tree_lanesL`` — end-to-end playouts/s of the tree strategy
  under both modes (playout-dominated on CPU, so noisier; informational).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import stages as S
from repro.core.domains.pgame import PGameDomain, optimal_root_action
from repro.core.metrics import search_overhead, strength
from repro.search import SearchConfig, SearchParams, search, search_batch

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=11)
SP = SearchParams(cp=0.7, max_depth=6)
BUDGETS = (32, 64, 128, 256, 512)
SEEDS = 12
TARGET = 0.7


def _curve(method, lanes, budgets, seeds):
    curve = {}
    for b in budgets:
        cfg = SearchConfig(method=method, budget=b, lanes=lanes, params=SP,
                           keep_tree=False)
        fn = jax.jit(lambda r: search(DOM, cfg, r).best_action)
        acts = [int(fn(jax.random.key(s))) for s in range(seeds)]
        curve[b] = strength(acts, optimal_root_action(DOM))
    return curve


def _select_stage_us(ws: str, lanes: int, tree, n_waves: int = 100) -> float:
    """Mean microseconds per Select wave (L selections) on a fixed tree."""
    sp = dataclasses.replace(SP, wave_select=ws)

    def body(i, acc):
        # per-iteration perturbation defeats loop-invariant hoisting
        t2 = tree.replace(visits=tree.visits.at[0].add(i))
        t3, sel = S.select_wave(t2, sp, lanes, jnp.asarray(True))
        return acc + sel["leaf"].sum() + t3.vloss.sum()

    fn = jax.jit(lambda: jax.lax.fori_loop(0, n_waves, body, jnp.int32(0)))
    fn().block_until_ready()
    best = float("inf")
    for _ in range(5):                # min-of-repeats rides out CPU jitter
        t0 = time.perf_counter()
        fn().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best / n_waves * 1e6


def _wave_us(fused: bool, lanes: int, tree, n_waves: int = 100) -> float:
    """Mean microseconds for one wave of TREE OPS (Select + Expand + Backup,
    DESIGN.md §14).  Playout is excluded — it is domain work untouched by
    the fusion — by backing up a constant value/prior instead of rolling
    out.  ``fused`` runs the megakernel decomposition (one lockstep descent
    + vectorized structural expand); unfused runs the pre-fusion stages
    (per-level Select dispatch, per-lane ``lax.scan`` Expand)."""
    from repro.kernels.search_wave import ref
    sp = dataclasses.replace(SP, wave_select="lockstep")
    val = jnp.zeros((lanes,), jnp.float32)
    pri = jnp.full((lanes, DOM.num_actions), 1.0 / DOM.num_actions,
                   jnp.float32)

    def body(i, acc):
        t2 = tree.replace(visits=tree.visits.at[0].add(i))
        if fused:
            t3, sel = S.select_wave_fused(t2, sp, lanes, jnp.asarray(True))
            t3, es = ref.expand_wave_struct(t3, sp, sel)
            t3, exp = ref.finish_expand(t3, DOM, es)
        else:
            t3, sel = S.select_wave(t2, sp, lanes, jnp.asarray(True))
            t3, exp = S.expand_wave(t3, DOM, sp, sel)
        po = {"path": exp["path"], "node": exp["node"],
              "is_new": exp["is_new"], "value": val, "priors": pri,
              "valid": exp["valid"]}
        t4 = S.backup_wave(t3, po)
        return acc + sel["leaf"].sum() + t4.vloss.sum() + t4.visits.sum()

    fn = jax.jit(lambda: jax.lax.fori_loop(0, n_waves, body, jnp.int32(0)))
    fn().block_until_ready()
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        fn().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best / n_waves * 1e6


def _e2e_playouts_per_s(ws: str, lanes: int, budget: int, nbatch: int) -> float:
    sp = dataclasses.replace(SP, wave_select=ws)
    cfg = SearchConfig(method="tree", budget=budget, lanes=lanes, params=sp,
                       keep_tree=False)
    fn = jax.jit(
        lambda r: search_batch([DOM] * nbatch, cfg, r, mesh=False).action_visits)
    fn(jax.random.key(0)).block_until_ready()
    iters = 3
    t0 = time.perf_counter()
    for i in range(iters):
        fn(jax.random.key(i)).block_until_ready()
    return nbatch * budget / ((time.perf_counter() - t0) / iters)


def _fused_select_rows(report, smoke: bool):
    # a representative mid-search tree: grown by the scan path so both modes
    # descend the identical structure
    grow = SearchConfig(method="tree", budget=256, lanes=8, params=SP)
    tree = jax.jit(lambda r: search(DOM, grow, r))(jax.random.key(0)).tree
    for lanes in ((8,) if smoke else (8, 16, 32)):
        us_scan = _select_stage_us("scan", lanes, tree)
        us_lock = _select_stage_us("lockstep", lanes, tree)
        report(f"select_wave_scan_lanes{lanes}", us_scan,
               f"selects/s={lanes / us_scan * 1e6:.0f}")
        report(f"select_wave_lockstep_lanes{lanes}", us_lock,
               f"selects/s={lanes / us_lock * 1e6:.0f} "
               f"speedup={us_scan / us_lock:.2f}x one [lanes,A] UCT pass/level")
    # megakernel gate rows (DESIGN.md §14): tree-op throughput of one fused
    # wave vs the per-level/per-lane unfused stages, same grown tree
    lanes = 8
    us_unf = _wave_us(False, lanes, tree)
    us_meg = _wave_us(True, lanes, tree)
    report(f"wave_unfused_lockstep_lanes{lanes}", us_unf,
           f"playouts/s={lanes / us_unf * 1e6:.0f}")
    report(f"wave_fused_mega_lanes{lanes}", us_meg,
           f"playouts/s={lanes / us_meg * 1e6:.0f} "
           f"speedup={us_unf / us_meg:.2f}x one S+E+B pass/wave")
    lanes, budget, nbatch = 8, 256, (4 if smoke else 8)
    ps_scan = _e2e_playouts_per_s("scan", lanes, budget, nbatch)
    ps_lock = _e2e_playouts_per_s("lockstep", lanes, budget, nbatch)
    ps_mega = _e2e_playouts_per_s("mega", lanes, budget, nbatch)
    report(f"select_e2e_tree_lanes{lanes}", 1e6 * budget * nbatch / ps_lock,
           f"lockstep={ps_lock:.0f}pl/s scan={ps_scan:.0f}pl/s "
           f"mega={ps_mega:.0f}pl/s speedup={ps_lock / ps_scan:.2f}x")


def run(report, smoke: bool = False):
    budgets = (16, 32) if smoke else BUDGETS
    seeds = 3 if smoke else SEEDS
    _fused_select_rows(report, smoke)
    t0 = time.perf_counter()
    seq = _curve("sequential", 1, budgets, seeds)
    report("seq_strength_curve", (time.perf_counter() - t0) * 1e6,
           " ".join(f"{b}:{s:.2f}" for b, s in seq.items()))

    for lanes in ((4,) if smoke else (4, 16)):
        pipe = _curve("pipeline", lanes, budgets, seeds)
        so = search_overhead(seq, pipe, TARGET)
        report(f"pipeline_lanes{lanes}_overhead", 0.0,
               f"SO@{TARGET}={so:.2f} curve=" +
               " ".join(f"{b}:{s:.2f}" for b, s in pipe.items()))

    for threads in ((16,) if smoke else (16, 64)):
        tp = _curve("tree", threads, budgets, seeds)
        so = search_overhead(seq, tp, TARGET)
        report(f"tree_parallel_t{threads}_overhead", 0.0,
               f"SO@{TARGET}={so:.2f} curve=" +
               " ".join(f"{b}:{s:.2f}" for b, s in tp.items()))
