"""Search overhead (paper §III-B): budget_parallel / budget_sequential to
reach a target strength, from strength-vs-budget curves; plus the direct
in-flight duplicate-rate signal vs concurrency.  All strategies go through
the unified ``repro.search`` API.
"""
from __future__ import annotations

import time

import jax

from repro.core.domains.pgame import PGameDomain, optimal_root_action
from repro.core.metrics import search_overhead, strength
from repro.search import SearchConfig, SearchParams, search

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=11)
SP = SearchParams(cp=0.7, max_depth=6)
BUDGETS = (32, 64, 128, 256, 512)
SEEDS = 12
TARGET = 0.7


def _curve(method, lanes, budgets, seeds):
    curve = {}
    for b in budgets:
        cfg = SearchConfig(method=method, budget=b, lanes=lanes, params=SP,
                           keep_tree=False)
        fn = jax.jit(lambda r: search(DOM, cfg, r).best_action)
        acts = [int(fn(jax.random.key(s))) for s in range(seeds)]
        curve[b] = strength(acts, optimal_root_action(DOM))
    return curve


def run(report, smoke: bool = False):
    budgets = (16, 32) if smoke else BUDGETS
    seeds = 3 if smoke else SEEDS
    t0 = time.perf_counter()
    seq = _curve("sequential", 1, budgets, seeds)
    report("seq_strength_curve", (time.perf_counter() - t0) * 1e6,
           " ".join(f"{b}:{s:.2f}" for b, s in seq.items()))

    for lanes in ((4,) if smoke else (4, 16)):
        pipe = _curve("pipeline", lanes, budgets, seeds)
        so = search_overhead(seq, pipe, TARGET)
        report(f"pipeline_lanes{lanes}_overhead", 0.0,
               f"SO@{TARGET}={so:.2f} curve=" +
               " ".join(f"{b}:{s:.2f}" for b, s in pipe.items()))

    for threads in ((16,) if smoke else (16, 64)):
        tp = _curve("tree", threads, budgets, seeds)
        so = search_overhead(seq, tp, TARGET)
        report(f"tree_parallel_t{threads}_overhead", 0.0,
               f"SO@{TARGET}={so:.2f} curve=" +
               " ".join(f"{b}:{s:.2f}" for b, s in tp.items()))
