"""Search overhead (paper §III-B): budget_parallel / budget_sequential to
reach a target strength, from strength-vs-budget curves; plus the direct
in-flight duplicate-rate signal vs concurrency.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.domains.pgame import PGameDomain, optimal_root_action
from repro.core.metrics import search_overhead, strength
from repro.core.pipeline import PipelineConfig, run_pipeline
from repro.core.sequential import run_sequential
from repro.core.stages import SearchParams
from repro.core.tree import root_action_by_visits
from repro.core.tree_parallel import run_tree_parallel

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=11)
SP = SearchParams(cp=0.7, max_depth=6)
BUDGETS = (32, 64, 128, 256, 512)
SEEDS = 12
TARGET = 0.7


def _curve(make_fn):
    curve = {}
    for b in BUDGETS:
        fn = jax.jit(make_fn(b))
        acts = [int(fn(jax.random.key(s))) for s in range(SEEDS)]
        curve[b] = strength(acts, optimal_root_action(DOM))
    return curve


def run(report):
    t0 = time.perf_counter()
    seq = _curve(lambda b: (lambda r: root_action_by_visits(
        run_sequential(DOM, SP, b, r)[0])))
    report("seq_strength_curve", (time.perf_counter() - t0) * 1e6,
           " ".join(f"{b}:{s:.2f}" for b, s in seq.items()))

    for lanes in (4, 16):
        pipe = _curve(lambda b: (lambda r: root_action_by_visits(
            run_pipeline(DOM, PipelineConfig(budget=b, lanes=lanes, params=SP), r)[0])))
        so = search_overhead(seq, pipe, TARGET)
        report(f"pipeline_lanes{lanes}_overhead", 0.0,
               f"SO@{TARGET}={so:.2f} curve=" +
               " ".join(f"{b}:{s:.2f}" for b, s in pipe.items()))

    for threads in (16, 64):
        tp = _curve(lambda b: (lambda r: root_action_by_visits(
            run_tree_parallel(DOM, SP, b, threads, r)[0])))
        so = search_overhead(seq, tp, TARGET)
        report(f"tree_parallel_t{threads}_overhead", 0.0,
               f"SO@{TARGET}={so:.2f} curve=" +
               " ".join(f"{b}:{s:.2f}" for b, s in tp.items()))
