"""Search overhead (paper §III-B): budget_parallel / budget_sequential to
reach a target strength, from strength-vs-budget curves; plus the direct
in-flight duplicate-rate signal vs concurrency.  All strategies go through
the unified ``repro.search`` API.

Also home of the lockstep-vs-scan Select rows (DESIGN.md §11):

* ``select_wave_{scan,lockstep}_lanesL`` — Select-stage throughput in
  isolation (one wave = L trajectory selections on a grown tree; the
  lockstep row's ``derived`` carries the speedup CI asserts on).
* ``select_e2e_tree_lanesL`` — end-to-end playouts/s of the tree strategy
  under both modes (playout-dominated on CPU, so noisier; informational).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import stages as S
from repro.core.domains.pgame import PGameDomain, optimal_root_action
from repro.core.metrics import search_overhead, strength
from repro.search import SearchConfig, SearchParams, search, search_batch

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=11)
SP = SearchParams(cp=0.7, max_depth=6)
BUDGETS = (32, 64, 128, 256, 512)
SEEDS = 12
TARGET = 0.7


def _curve(method, lanes, budgets, seeds):
    curve = {}
    for b in budgets:
        cfg = SearchConfig(method=method, budget=b, lanes=lanes, params=SP,
                           keep_tree=False)
        fn = jax.jit(lambda r: search(DOM, cfg, r).best_action)
        acts = [int(fn(jax.random.key(s))) for s in range(seeds)]
        curve[b] = strength(acts, optimal_root_action(DOM))
    return curve


def _select_stage_us(ws: str, lanes: int, tree, n_waves: int = 100) -> float:
    """Mean microseconds per Select wave (L selections) on a fixed tree."""
    sp = dataclasses.replace(SP, wave_select=ws)

    def body(i, acc):
        t2 = dict(tree)
        # per-iteration perturbation defeats loop-invariant hoisting
        t2["visits"] = tree["visits"].at[0].add(i)
        t3, sel = S.select_wave(t2, sp, lanes, jnp.asarray(True))
        return acc + sel["leaf"].sum() + t3["vloss"].sum()

    fn = jax.jit(lambda: jax.lax.fori_loop(0, n_waves, body, jnp.int32(0)))
    fn().block_until_ready()
    best = float("inf")
    for _ in range(5):                # min-of-repeats rides out CPU jitter
        t0 = time.perf_counter()
        fn().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best / n_waves * 1e6


def _e2e_playouts_per_s(ws: str, lanes: int, budget: int, nbatch: int) -> float:
    sp = dataclasses.replace(SP, wave_select=ws)
    cfg = SearchConfig(method="tree", budget=budget, lanes=lanes, params=sp,
                       keep_tree=False)
    fn = jax.jit(
        lambda r: search_batch([DOM] * nbatch, cfg, r, mesh=False).action_visits)
    fn(jax.random.key(0)).block_until_ready()
    iters = 3
    t0 = time.perf_counter()
    for i in range(iters):
        fn(jax.random.key(i)).block_until_ready()
    return nbatch * budget / ((time.perf_counter() - t0) / iters)


def _fused_select_rows(report, smoke: bool):
    # a representative mid-search tree: grown by the scan path so both modes
    # descend the identical structure
    grow = SearchConfig(method="tree", budget=256, lanes=8, params=SP)
    tree = jax.jit(lambda r: search(DOM, grow, r))(jax.random.key(0)).tree
    for lanes in ((8,) if smoke else (8, 16, 32)):
        us_scan = _select_stage_us("scan", lanes, tree)
        us_lock = _select_stage_us("lockstep", lanes, tree)
        report(f"select_wave_scan_lanes{lanes}", us_scan,
               f"selects/s={lanes / us_scan * 1e6:.0f}")
        report(f"select_wave_lockstep_lanes{lanes}", us_lock,
               f"selects/s={lanes / us_lock * 1e6:.0f} "
               f"speedup={us_scan / us_lock:.2f}x one [lanes,A] UCT pass/level")
    lanes, budget, nbatch = 8, 256, (4 if smoke else 8)
    ps_scan = _e2e_playouts_per_s("scan", lanes, budget, nbatch)
    ps_lock = _e2e_playouts_per_s("lockstep", lanes, budget, nbatch)
    report(f"select_e2e_tree_lanes{lanes}", 1e6 * budget * nbatch / ps_lock,
           f"lockstep={ps_lock:.0f}pl/s scan={ps_scan:.0f}pl/s "
           f"speedup={ps_lock / ps_scan:.2f}x")


def run(report, smoke: bool = False):
    budgets = (16, 32) if smoke else BUDGETS
    seeds = 3 if smoke else SEEDS
    _fused_select_rows(report, smoke)
    t0 = time.perf_counter()
    seq = _curve("sequential", 1, budgets, seeds)
    report("seq_strength_curve", (time.perf_counter() - t0) * 1e6,
           " ".join(f"{b}:{s:.2f}" for b, s in seq.items()))

    for lanes in ((4,) if smoke else (4, 16)):
        pipe = _curve("pipeline", lanes, budgets, seeds)
        so = search_overhead(seq, pipe, TARGET)
        report(f"pipeline_lanes{lanes}_overhead", 0.0,
               f"SO@{TARGET}={so:.2f} curve=" +
               " ".join(f"{b}:{s:.2f}" for b, s in pipe.items()))

    for threads in ((16,) if smoke else (16, 64)):
        tp = _curve("tree", threads, budgets, seeds)
        so = search_overhead(seq, tp, TARGET)
        report(f"tree_parallel_t{threads}_overhead", 0.0,
               f"SO@{TARGET}={so:.2f} curve=" +
               " ".join(f"{b}:{s:.2f}" for b, s in tp.items()))
