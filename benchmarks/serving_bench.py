"""Sustained serving traffic at 2x oversubscription (DESIGN.md §12):
tokens/s and TTFT through the full request lifecycle — admission queue,
continuous slot refill, per-token batched search — comparing the cold
per-token path against KV splice + subtree reuse.

Twice as many requests as slots are submitted up front, so the run
exercises queue wait, mid-run refills, and the searcher carry surviving
admissions.  Timing excludes compilation: a warmup wave drains first, then
a fresh wave of requests is timed against the already-compiled programs.
CI asserts the reuse row lands in BENCH_pr.json and beats the cold row.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.models.base import ModelConfig, get_family
from repro.serving import (EngineConfig, MCTSDecodeConfig, Request,
                           ServingEngine, ServingStats)

CFG = ModelConfig(name="bench-lm", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  dtype="float32", ce_chunk=16, remat=False)


def _requests(n, plen, max_new, uid0=0):
    rng = np.random.default_rng(uid0 + 1)
    return [Request(uid=uid0 + i,
                    prompt=rng.integers(1, CFG.vocab_size,
                                        size=plen).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def run(report, smoke: bool = False):
    slots = 2
    load = 2 * slots                      # 2x oversubscribed
    plen = 64 if smoke else 96            # long prompts: per-token prefill
    max_new = 4 if smoke else 8           # is the cost KV splice removes
    max_seq = plen + max_new + 1
    budget, lanes, depth, roll = ((6, 2, 2, 1) if smoke else (16, 4, 4, 2))
    fam = get_family(CFG)
    params = fam.init(CFG, jax.random.key(0))

    times = {}
    for name, knobs in (("cold", {}),
                        ("reuse", {"kv_splice": True, "tree_reuse": True})):
        dcfg = MCTSDecodeConfig(num_actions=4, budget=budget, lanes=lanes,
                                search_depth=depth, rollout_len=roll, **knobs)
        eng = ServingEngine(CFG, params, EngineConfig(
            max_batch=slots, max_seq=max_seq, decode="mcts", mcts=dcfg,
            mesh=False))
        # warmup wave: compile admit/step at full occupancy + refill
        for r in _requests(load, plen, max_new, uid0=0):
            eng.submit(r)
        eng.run_until_drained()
        # timed waves on the compiled engine; best-of-3 (CI gates on this)
        best, snap, tokens = float("inf"), None, 0
        for wave in range(3):
            eng.stats = ServingStats()
            for r in _requests(load, plen, max_new, uid0=1000 * (wave + 1)):
                eng.submit(r)
            t0 = time.perf_counter()
            out = eng.run_until_drained()
            wall = time.perf_counter() - t0
            assert out["tokens"] == load * max_new, out["tokens"]
            if wall < best:
                best, snap, tokens = wall, out["stats"], out["tokens"]
        times[name] = best
        extra = ("" if name == "cold"
                 else f" speedup_x={times['cold'] / best:.2f}")
        report(f"serving_{name}", best * 1e6,
               f"tokens_per_s={tokens / best:,.1f} "
               f"ttft_ms={snap['serving/ttft_mean'] * 1e3:.1f}{extra}")
