"""Strength-speedup + search overhead (paper §II def. 2, §III-B).

At a fixed playout budget, measures the fraction of seeds whose recommended
root action is optimal (exact enumeration oracle), for: sequential, the
pipeline (varying in-flight lanes), tree parallelization with virtual loss
(varying threads), root and leaf parallelization — the paper's §IV baselines.

The paper's claim: the pipeline holds strength near sequential (bounded
in-flight window) where tree parallelization degrades with threads.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.domains.pgame import PGameDomain, optimal_root_action
from repro.core.leaf_parallel import run_leaf_parallel
from repro.core.metrics import duplicate_rate, strength
from repro.core.pipeline import PipelineConfig, run_pipeline
from repro.core.root_parallel import root_parallel_action, run_root_parallel
from repro.core.sequential import run_sequential
from repro.core.stages import SearchParams
from repro.core.tree import root_action_by_visits
from repro.core.tree_parallel import run_tree_parallel

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=3)
SP = SearchParams(cp=0.7, max_depth=6)
BUDGET = 256
SEEDS = 16


def run(report):
    opt = optimal_root_action(DOM)

    def bench(name, fn, extra=""):
        t0 = time.perf_counter()
        actions, dups = [], []
        for s in range(SEEDS):
            a, d = fn(jax.random.key(s))
            actions.append(int(a))
            dups.append(int(d))
        us = (time.perf_counter() - t0) * 1e6 / SEEDS
        st = strength(actions, opt)
        report(name, us, f"strength={st:.2f} dup_rate="
                         f"{duplicate_rate(int(np.mean(dups)), BUDGET):.3f}{extra}")
        return st

    seq_j = jax.jit(lambda r: (root_action_by_visits(run_sequential(DOM, SP, BUDGET, r)[0]),
                               jax.numpy.int32(0)))
    st_seq = bench("sequential", lambda r: seq_j(r))

    for lanes in (2, 4, 8, 16):
        cfg = PipelineConfig(budget=BUDGET, lanes=lanes, params=SP)
        pj = jax.jit(lambda r: (
            root_action_by_visits(run_pipeline(DOM, cfg, r)[0]),
            run_pipeline(DOM, cfg, r)[1]["duplicates"]))
        st = bench(f"pipeline_lanes{lanes}", pj,
                   extra=f" strength_speedup={0.0 if st_seq == 0 else 0.0:.0f}")
    for threads in (8, 16, 32, 64):
        tj = jax.jit(lambda r: (
            root_action_by_visits(run_tree_parallel(DOM, SP, BUDGET, threads, r)[0]),
            run_tree_parallel(DOM, SP, BUDGET, threads, r)[1]["duplicates"]))
        bench(f"tree_parallel_t{threads}", tj)
    for workers in (4, 16):
        rj = jax.jit(lambda r: (
            root_parallel_action(run_root_parallel(DOM, SP, BUDGET, workers, r)[0]),
            jax.numpy.int32(0)))
        bench(f"root_parallel_w{workers}", rj)
    lj = jax.jit(lambda r: (
        root_action_by_visits(run_leaf_parallel(DOM, SP, BUDGET, 4, r)[0]),
        jax.numpy.int32(0)))
    bench("leaf_parallel_w4", lj)
