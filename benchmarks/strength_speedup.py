"""Strength-speedup + search overhead (paper §II def. 2, §III-B).

At a fixed playout budget, measures the fraction of seeds whose recommended
root action is optimal (exact enumeration oracle), for every registered
strategy via the unified ``repro.search`` API: sequential, the pipeline
(varying in-flight lanes), tree parallelization with virtual loss (varying
threads), root and leaf parallelization — the paper's §IV baselines.

The paper's claim: the pipeline holds strength near sequential (bounded
in-flight window) where tree parallelization degrades with threads.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.domains.pgame import PGameDomain, optimal_root_action
from repro.core.metrics import duplicate_rate, strength
from repro.search import SearchConfig, SearchParams, search

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=3)
SP = SearchParams(cp=0.7, max_depth=6)
BUDGET = 256
SEEDS = 16


def run(report, smoke: bool = False):
    budget = 32 if smoke else BUDGET
    seeds = 3 if smoke else SEEDS
    opt = optimal_root_action(DOM)

    def bench(name, method, lanes):
        cfg = SearchConfig(method=method, budget=budget, lanes=lanes,
                           params=SP, keep_tree=False)
        fn = jax.jit(lambda r: search(DOM, cfg, r))
        t0 = time.perf_counter()
        actions, dups = [], []
        for s in range(seeds):
            res = fn(jax.random.key(s))
            actions.append(int(res.best_action))
            dups.append(int(res.stats["duplicates"]))
        us = (time.perf_counter() - t0) * 1e6 / seeds
        st = strength(actions, opt)
        report(name, us, f"strength={st:.2f} dup_rate="
                         f"{duplicate_rate(int(np.mean(dups)), budget):.3f}")
        return st

    bench("sequential", "sequential", 1)
    for lanes in ((4,) if smoke else (2, 4, 8, 16)):
        bench(f"pipeline_lanes{lanes}", "pipeline", lanes)
    for threads in ((16,) if smoke else (8, 16, 32, 64)):
        bench(f"tree_parallel_t{threads}", "tree", threads)
    for workers in ((4,) if smoke else (4, 16)):
        bench(f"root_parallel_w{workers}", "root", workers)
    bench("leaf_parallel_w4", "leaf", 4)
