"""Kernel micro-benchmarks: ref-path wall time on CPU (correctness-scale) +
the analytic VMEM working set / MXU utilization notes per kernel config.

Real TPU timing is out of scope on this container; the roofline for the
kernels' target shapes is derived in EXPERIMENTS.md §Roofline from the
dry-run HLO instead.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _t(f, *a):
    f(*a)
    t0 = time.perf_counter()
    jax.block_until_ready(f(*a))
    return (time.perf_counter() - t0) * 1e6


def run(report, smoke: bool = False):
    # smoke: correctness-scale shapes so the CI perf job touches every kernel
    t_seq = 64 if smoke else 256
    t_kv = 256 if smoke else 2048
    n_nodes = 128 if smoke else 1024
    from repro.kernels.flash_attention import ops as fa
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (2, t_seq, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, t_seq, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, t_seq, 2, 64), jnp.float32)
    us = _t(jax.jit(lambda q, k, v: fa.flash_attention(
        q, k, v, causal=True, use_ref=True)), q, k, v)
    vmem_kb = (128 * 64 + 128 * 64 * 2 + 128 * 64) * 4 / 1024
    report(f"flash_attention_ref_b2s{t_seq}", us,
           f"kernel_tile=128x128xD64 vmem_working_set~{vmem_kb:.0f}KB")

    from repro.kernels.decode_attention import ops as da
    q1 = jax.random.normal(ks[0], (4, 1, 8, 128), jnp.float32)
    kc = jax.random.normal(ks[1], (4, t_kv, 2, 128), jnp.float32)
    vc = jax.random.normal(ks[2], (4, t_kv, 2, 128), jnp.float32)
    vl = jnp.full((4,), t_kv, jnp.int32)
    us = _t(jax.jit(lambda q, k, v, l: da.decode_attention(
        q, k, v, l, use_ref=True)), q1, kc, vc, vl)
    report(f"decode_attention_ref_kv{t_kv}", us, "split-K blk 512, SMEM lengths")

    from repro.kernels.rwkv6_scan import ops as ro
    r = jax.random.normal(ks[0], (2, t_seq, 4, 64)) * 0.5
    kk = jax.random.normal(ks[1], (2, t_seq, 4, 64)) * 0.5
    vv = jax.random.normal(ks[2], (2, t_seq, 4, 64)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[0], (2, t_seq, 4, 64))) * 0.2 + 0.8
    u = jax.random.normal(ks[1], (4, 64)) * 0.3
    st = jnp.zeros((2, 4, 64, 64))
    us = _t(jax.jit(lambda *a: ro.wkv6_chunked(*a, chunk=32)[0]), r, kk, vv, w, u, st)
    report(f"wkv6_chunked_t{t_seq}", us, "chunk=32 matmul-form, state 64x64 VMEM")

    from repro.kernels.ssm_scan import ops as so
    x = jax.random.normal(ks[0], (2, t_seq, 4, 64)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, t_seq, 4)))
    A = -jnp.exp(jax.random.normal(ks[2], (4,)) * 0.3)
    Bm = jax.random.normal(ks[0], (2, t_seq, 64)) * 0.5
    Cm = jax.random.normal(ks[1], (2, t_seq, 64)) * 0.5
    D = jnp.ones((4,))
    st = jnp.zeros((2, 4, 64, 64))
    us = _t(jax.jit(lambda *a: so.ssd_chunked(*a, chunk=64)[0]),
            x, dt, A, Bm, Cm, D, st)
    report(f"ssd_chunked_t{t_seq}", us, "chunk=64 SSD matmul-form, state 64x64 VMEM")

    from repro.kernels.uct_select import ops as uo
    n = jax.random.randint(ks[0], (n_nodes, 64), 0, 50).astype(jnp.float32)
    w2 = jax.random.normal(ks[1], (n_nodes, 64)) * 3
    vl2 = jnp.zeros((n_nodes, 64))
    pn = n.sum(-1) + 1
    us = _t(jax.jit(lambda *a: uo.uct_argmax(*a, cp=1.4, use_ref=True)),
            n, w2, vl2, pn)
    report(f"uct_argmax_ref_{n_nodes}x64", us, "fused score+argmax, lane-padded 128")

    # lockstep wave shapes (DESIGN.md §11): r = lanes rows per launch, rows
    # duplicating a shared parent (co-located lanes), ragged valid masks
    for lanes in ((8,) if smoke else (8, 16, 32)):
        rows = jnp.arange(lanes) % 3
        nw = n[:3][rows]
        ww = w2[:3][rows]
        vlw = jax.random.randint(ks[2], (lanes, 64), 0, 3).astype(jnp.float32)
        pnw = nw.sum(-1) + 1
        va = jax.random.bernoulli(ks[2], 0.7, (lanes, 64)).at[:, 0].set(True)
        us = _t(jax.jit(lambda *a: uo.uct_argmax(
            *a, cp=1.4, valid=va, use_ref=True)), nw, ww, vlw, pnw)
        report(f"uct_argmax_wave_ref_r{lanes}", us,
               f"jnp oracle at the wave shape [{lanes},128], dup parents")
