"""Best-action-found rate at equal wall-clock (WU-UCT vs virtual loss,
DESIGN.md §15; running assignment, §16): {scan, vloss-lockstep,
wu-lockstep, wu-running-lockstep} x lanes {4, 8} on the P-game through the
*pipeline* strategy — the one CPU-visible path where playouts stay in
flight across Select calls, so the two ``vl_mode`` bookkeepings actually
diverge (tree-lockstep drains every round and the modes coincide
bit-for-bit there).

Equal wall-clock protocol:

* ``vloss_lockstep``, ``wu_lockstep`` and ``wu_running_lockstep`` run the
  SAME budget — the modes trace the same compute graph (one in-flight
  plane, one formula branch; running adds a lane scan that is in the graph
  either way), so equal budget IS equal wall-clock, and their comparison
  is seed-deterministic (no timing noise in the gate);
* ``scan`` is re-budgeted so its measured search time matches lockstep's
  (calibrated per lanes count, clamped to [B/2, 2B] against CI jitter) —
  informational, not gated.

CI gates, on the smoke rows (lanes=8) — each gate is a matched pair (same
cp/budget/seeds inside the pair, only the knob under test differs):

* ``strength(wu_lockstep) >= strength(vloss_lockstep)`` at cp=0.1 —
  removing the virtual-loss Q corruption must not cost strength at equal
  compute.  cp=0.1 keeps selection exploit-heavy, where corrupted Q
  actually changes decisions;
* ``strength(wu_running_lockstep) >= strength(wu_indep_lockstep)`` and
  ``dup(wu_running_lockstep) < dup(wu_indep_lockstep)`` at cp=0.3 — the
  within-level running assignment must spread co-located lanes (fewer
  duplicate selections) without costing strength.  cp=0.3 gives siblings
  enough exploration credit that within-level stacking is the binding
  waste (at cp=0.1 lanes re-converge on the Q-argmax child regardless of
  assignment and the comparison measures noise).

Every row reports its mean per-search ``duplicates`` stat as ``dup=`` so
the decorrelation is visible alongside strength.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.domains.pgame import PGameDomain, optimal_root_action
from repro.search import SearchConfig, SearchParams, search

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=11)
CP = 0.1
BUDGET = 96
# the level_assign pair runs at its own matched settings (module docstring)
RUN_CP = 0.3
RUN_BUDGET = 80
METHOD = "pipeline"


def _cfg(ws: str, vl_mode: str, lanes: int, budget: int,
         level_assign: str = "independent", cp: float = CP) -> SearchConfig:
    sp = SearchParams(cp=cp, max_depth=6, wave_select=ws, vl_mode=vl_mode,
                      level_assign=level_assign)
    return SearchConfig(method=METHOD, budget=budget, lanes=lanes,
                        params=sp, keep_tree=False)


def _searcher(cfg: SearchConfig):
    def one(r):
        res = search(DOM, cfg, r)
        return res.action_visits, res.stats["duplicates"]
    fn = jax.jit(one)
    jax.block_until_ready(fn(jax.random.key(0)))   # compile outside timing
    return fn


def _time_one(fn, iters: int = 3) -> float:
    best = float("inf")
    for i in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(jax.random.key(i)))
        best = min(best, time.perf_counter() - t0)
    return best


def _strength(fn, seeds: int):
    """(best-action hit rate, mean per-search duplicates) over seeds."""
    opt = optimal_root_action(DOM)
    hits, dups = 0, 0.0
    for s in range(seeds):
        visits, dup = fn(jax.random.key(s))
        hits += int(np.argmax(np.asarray(visits)) == opt)
        dups += float(dup)
    return hits / seeds, dups / seeds


def run(report, smoke: bool = False):
    seeds = 24 if smoke else 32
    for lanes in ((8,) if smoke else (4, 8)):
        lock = _searcher(_cfg("lockstep", "loss", lanes, BUDGET))
        t_lock = _time_one(lock)
        t_scan = _time_one(_searcher(_cfg("scan", "loss", lanes, BUDGET)))
        # scan's equal-wall-clock budget: what it completes in t_lock
        sb = int(round(BUDGET * t_lock / max(t_scan, 1e-9)))
        sb = max(BUDGET // 2, min(2 * BUDGET, sb))
        scan_eq = _searcher(_cfg("scan", "loss", lanes, sb))
        wu = _searcher(_cfg("lockstep", "wu", lanes, BUDGET))
        indep = _searcher(_cfg("lockstep", "wu", lanes, RUN_BUDGET,
                               "independent", RUN_CP))
        run_ = _searcher(_cfg("lockstep", "wu", lanes, RUN_BUDGET,
                              "running", RUN_CP))
        for name, fn, b, t in (
                ("scan", scan_eq, sb, _time_one(scan_eq)),
                ("vloss_lockstep", lock, BUDGET, t_lock),
                ("wu_lockstep", wu, BUDGET, _time_one(wu)),
                ("wu_indep_lockstep", indep, RUN_BUDGET, _time_one(indep)),
                ("wu_running_lockstep", run_, RUN_BUDGET, _time_one(run_))):
            s, d = _strength(fn, seeds)
            report(f"strength_{name}_lanes{lanes}", t * 1e6,
                   f"strength={s:.3f} dup={d:.2f} budget={b} seeds={seeds}")
