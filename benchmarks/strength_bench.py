"""Best-action-found rate at equal wall-clock (WU-UCT vs virtual loss,
DESIGN.md §15): {scan, vloss-lockstep, wu-lockstep} x lanes {4, 8} on the
P-game through the *pipeline* strategy — the one CPU-visible path where
playouts stay in flight across Select calls, so the two ``vl_mode``
bookkeepings actually diverge (tree-lockstep drains every round and the
modes coincide bit-for-bit there).

Equal wall-clock protocol:

* ``vloss_lockstep`` and ``wu_lockstep`` run the SAME budget — the two
  modes trace the same compute graph (one in-flight plane, one formula
  branch), so equal budget IS equal wall-clock, and their comparison is
  seed-deterministic (no timing noise in the gate);
* ``scan`` is re-budgeted so its measured search time matches lockstep's
  (calibrated per lanes count, clamped to [B/2, 2B] against CI jitter) —
  informational, not gated.

CI gates ``strength(wu_lockstep) >= strength(vloss_lockstep)`` on the
smoke row (lanes=8): removing the virtual-loss Q corruption must not cost
strength at equal compute.  cp=0.1 keeps selection exploit-heavy, where
corrupted Q actually changes decisions.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.domains.pgame import PGameDomain, optimal_root_action
from repro.search import SearchConfig, SearchParams, search

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=11)
CP = 0.1
BUDGET = 96
METHOD = "pipeline"


def _cfg(ws: str, vl_mode: str, lanes: int, budget: int) -> SearchConfig:
    sp = SearchParams(cp=CP, max_depth=6, wave_select=ws, vl_mode=vl_mode)
    return SearchConfig(method=METHOD, budget=budget, lanes=lanes,
                        params=sp, keep_tree=False)


def _searcher(cfg: SearchConfig):
    fn = jax.jit(lambda r: search(DOM, cfg, r).action_visits)
    fn(jax.random.key(0)).block_until_ready()      # compile outside timing
    return fn


def _time_one(fn, iters: int = 3) -> float:
    best = float("inf")
    for i in range(iters):
        t0 = time.perf_counter()
        fn(jax.random.key(i)).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def _strength(fn, seeds: int) -> float:
    opt = optimal_root_action(DOM)
    hits = sum(int(np.argmax(np.asarray(fn(jax.random.key(s))))) == opt
               for s in range(seeds))
    return hits / seeds


def run(report, smoke: bool = False):
    seeds = 24 if smoke else 32
    for lanes in ((8,) if smoke else (4, 8)):
        lock = _searcher(_cfg("lockstep", "loss", lanes, BUDGET))
        t_lock = _time_one(lock)
        t_scan = _time_one(_searcher(_cfg("scan", "loss", lanes, BUDGET)))
        # scan's equal-wall-clock budget: what it completes in t_lock
        sb = int(round(BUDGET * t_lock / max(t_scan, 1e-9)))
        sb = max(BUDGET // 2, min(2 * BUDGET, sb))
        scan_eq = _searcher(_cfg("scan", "loss", lanes, sb))
        wu = _searcher(_cfg("lockstep", "wu", lanes, BUDGET))
        for name, fn, b, t in (("scan", scan_eq, sb, _time_one(scan_eq)),
                               ("vloss_lockstep", lock, BUDGET, t_lock),
                               ("wu_lockstep", wu, BUDGET, _time_one(wu))):
            s = _strength(fn, seeds)
            report(f"strength_{name}_lanes{lanes}", t * 1e6,
                   f"strength={s:.3f} budget={b} seeds={seeds}")
