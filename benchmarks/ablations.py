"""Ablations on the paper's knobs (beyond-paper quantification):

* virtual-loss weight — decorrelation vs pessimism trade-off (dup rate +
  strength at fixed budget/lanes);
* in-flight concurrency (lanes) at fixed budget — staleness growth, the ILD
  compromise dial of §V-A;
* MoE capacity factor — dropped-token fraction vs parity with the dropless
  dispatch (substrate knob exercised by deepseek/grok cells).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.domains.pgame import PGameDomain, optimal_root_action
from repro.core.metrics import strength
from repro.search import SearchConfig, SearchParams, search

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=5)
BUDGET = 256
SEEDS = 10


def _strength_dup(sp, lanes, budget=BUDGET, seeds=SEEDS):
    """(strength, dup_rate, dup_within_rate, dup_cross_rate) — the dup split
    attributes decorrelation to its source: within-level stacking (what
    ``level_assign="running"`` removes) vs cross-wave in-flight overlap."""
    cfg = SearchConfig(method="pipeline", budget=budget, lanes=lanes,
                       params=sp, keep_tree=False)
    f = jax.jit(lambda r: search(DOM, cfg, r))
    acts, dups, dw, dc = [], [], [], []
    for s in range(seeds):
        res = f(jax.random.key(s))
        acts.append(int(res.best_action))
        dups.append(int(res.stats["duplicates"]))
        dw.append(int(res.extras["dup_within"]))
        dc.append(int(res.extras["dup_cross"]))
    return (strength(acts, optimal_root_action(DOM)),
            float(np.mean(dups)) / budget,
            float(np.mean(dw)) / budget,
            float(np.mean(dc)) / budget)


def run(report, smoke: bool = False):
    budget = 32 if smoke else BUDGET
    seeds = 3 if smoke else SEEDS
    # virtual-loss weight ablation at lanes=8
    for vlw in ((0.0, 1.0) if smoke else (0.0, 0.5, 1.0, 3.0)):
        t0 = time.perf_counter()
        st, dup, dw, dc = _strength_dup(
            SearchParams(cp=0.7, max_depth=6, vl_weight=vlw), 8, budget,
            seeds)
        report(f"ablate_vl_weight_{vlw}", (time.perf_counter() - t0) * 1e6,
               f"strength={st:.2f} dup_rate={dup:.3f} "
               f"dup_within={dw:.3f} dup_cross={dc:.3f}")

    # in-flight concurrency (the ILD staleness dial)
    for lanes in ((1, 16) if smoke else (1, 4, 16, 32)):
        t0 = time.perf_counter()
        st, dup, dw, dc = _strength_dup(SearchParams(cp=0.7, max_depth=6),
                                        lanes, budget, seeds)
        report(f"ablate_inflight_lanes{lanes}", (time.perf_counter() - t0) * 1e6,
               f"strength={st:.2f} dup_rate={dup:.3f} dup_within={dw:.3f} "
               f"dup_cross={dc:.3f} in_flight={4 * lanes}")

    # within-level assignment (DESIGN.md §16): the running scan should move
    # dup_within toward zero at fixed budget/lanes; dup_cross is untouched
    for la in ("independent", "running"):
        t0 = time.perf_counter()
        st, dup, dw, dc = _strength_dup(
            SearchParams(cp=0.7, max_depth=6, wave_select="lockstep",
                         level_assign=la), 8, budget, seeds)
        report(f"ablate_level_assign_{la}", (time.perf_counter() - t0) * 1e6,
               f"strength={st:.2f} dup_rate={dup:.3f} "
               f"dup_within={dw:.3f} dup_cross={dc:.3f}")

    # MoE capacity factor: drop fraction + parity vs dropless dispatch
    from repro.models.base import ModelConfig
    from repro.models import moe as M
    cfg0 = ModelConfig(name="ab", family="moe", n_layers=1, d_model=32,
                       n_heads=4, d_ff=0, vocab_size=64, dtype="float32",
                       n_experts=8, moe_topk=2, d_ff_expert=16, moe_groups=2)
    p = M.init_moe_ffn(cfg0, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (256, 32))
    y_dropless = M.moe_ffn(cfg0.replace(moe_impl="ragged"), p, x)[0]
    for cap in (1.0, 1.25, 2.0, 8.0):
        cfg = cfg0.replace(moe_capacity=cap)
        t0 = time.perf_counter()
        y = M.moe_ffn(cfg, p, x)[0]
        us = (time.perf_counter() - t0) * 1e6
        # rows that came back all-zero from the routed experts were dropped
        diff = float(jnp.abs(y - y_dropless).max())
        changed = float((jnp.abs(y - y_dropless).max(-1) > 1e-6).mean())
        report(f"ablate_moe_capacity_{cap}", us,
               f"affected_token_frac={changed:.3f} max_diff={diff:.3f}")
