"""MCTS-LM decode throughput (the paper's technique as a serving feature):
playouts/s of the pipelined search over a tiny LM evaluator through the
unified ``repro.search`` API — lanes sweep, batched multi-root search
(``search_batch``) over several decode requests in one device program, and
the KV-cached vs uncached domain comparison (DESIGN.md §10) — the modern
instantiation where Playout = NN evaluation (DESIGN.md §2)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.domains.lm_decode import CachedLMDecodeDomain, LMDecodeDomain
from repro.models.base import ModelConfig, get_family
from repro.search import SearchConfig, SearchParams, search, search_batch

CFG = ModelConfig(name="bench-lm", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  dtype="float32", ce_chunk=16, remat=False)
BUDGET = 48


def run(report, smoke: bool = False):
    budget = 8 if smoke else BUDGET
    fam = get_family(CFG)
    params = fam.init(CFG, jax.random.key(0))

    def domain(prompt):
        return LMDecodeDomain(cfg=CFG, params=params,
                              prompt=jnp.asarray(prompt, jnp.int32),
                              num_actions=4, search_depth=6, rollout_len=3)

    dom = domain([1, 2, 3, 4])
    sp = SearchParams(cp=1.0, max_depth=6, puct=True)
    for lanes in ((1, 4) if smoke else (1, 2, 4, 8)):
        cfg = SearchConfig(method="pipeline", budget=budget, lanes=lanes,
                           params=sp, keep_tree=False)
        f = jax.jit(lambda r: search(dom, cfg, r).action_visits)
        f(jax.random.key(0))
        t0 = time.perf_counter()
        jax.block_until_ready(f(jax.random.key(1)))
        dt = time.perf_counter() - t0
        report(f"mcts_lm_decode_lanes{lanes}", dt * 1e6,
               f"playouts_per_s={budget / dt:,.1f}")

    # batched multi-root: 4 decode requests (distinct prompts), one program
    doms = [domain(p) for p in ([1, 2, 3, 4], [5, 6, 7, 8],
                                [9, 10, 11, 12], [2, 4, 6, 8])]
    cfg = SearchConfig(method="pipeline", budget=budget, lanes=4,
                       params=sp, keep_tree=False)
    f = jax.jit(lambda r: search_batch(doms, cfg, r).action_visits)
    f(jax.random.key(0))
    t0 = time.perf_counter()
    jax.block_until_ready(f(jax.random.key(1)))
    dt = time.perf_counter() - t0
    report("mcts_lm_decode_batch4", dt * 1e6,
           f"total_playouts_per_s={4 * budget / dt:,.1f}")

    # KV-cached vs uncached domain at the ISSUE's reference point
    # (rollout_len=4, search_depth=8, a 32-token prompt): the uncached
    # domain re-runs the whole prefix per expand/playout token, the cached
    # one prefills once per search and pays one incremental step per token
    # (DESIGN.md §10).  CI asserts the cached row lands in BENCH_pr.json
    # and is faster.
    prompt32 = list(range(1, 33))
    sp8 = SearchParams(cp=1.0, max_depth=8, puct=True)
    cfg = SearchConfig(method="pipeline", budget=budget, lanes=4,
                       params=sp8, keep_tree=False)
    times = {}
    for name, cls in (("uncached", LMDecodeDomain),
                      ("cached", CachedLMDecodeDomain)):
        dom = cls(cfg=CFG, params=params,
                  prompt=jnp.asarray(prompt32, jnp.int32),
                  num_actions=4, search_depth=8, rollout_len=4)
        f = jax.jit(lambda r, d=dom: search(d, cfg, r).action_visits)
        f(jax.random.key(0))
        best = float("inf")
        for rep in range(3):            # best-of-3: CI gates on this margin
            t0 = time.perf_counter()
            jax.block_until_ready(f(jax.random.key(1 + rep)))
            best = min(best, time.perf_counter() - t0)
        times[name] = best
        extra = ("" if name == "uncached" else
                 f" speedup_x={times['uncached'] / best:.2f}")
        report(f"mcts_lm_decode_{name}", best * 1e6,
               f"playouts_per_s={budget / best:,.1f}{extra}")
