"""MCTS-LM decode throughput (the paper's technique as a serving feature):
playouts/s of the pipelined search over a tiny LM evaluator, lanes sweep —
the modern instantiation where Playout = NN evaluation (DESIGN.md §2)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.domains.lm_decode import LMDecodeDomain
from repro.core.pipeline import PipelineConfig, run_pipeline
from repro.core.stages import SearchParams
from repro.models.base import ModelConfig, get_family

CFG = ModelConfig(name="bench-lm", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  dtype="float32", ce_chunk=16, remat=False)
BUDGET = 48


def run(report):
    fam = get_family(CFG)
    params = fam.init(CFG, jax.random.key(0))
    dom = LMDecodeDomain(cfg=CFG, params=params,
                         prompt=jnp.array([1, 2, 3, 4], jnp.int32),
                         num_actions=4, search_depth=6, rollout_len=3)
    sp = SearchParams(cp=1.0, max_depth=6, puct=True)
    for lanes in (1, 2, 4, 8):
        cfg = PipelineConfig(budget=BUDGET, lanes=lanes, params=sp)
        f = jax.jit(lambda r: run_pipeline(dom, cfg, r)[0]["visits"])
        f(jax.random.key(0))
        t0 = time.perf_counter()
        jax.block_until_ready(f(jax.random.key(1)))
        dt = time.perf_counter() - t0
        report(f"mcts_lm_decode_lanes{lanes}", dt * 1e6,
               f"playouts_per_s={BUDGET / dt:,.1f}")
