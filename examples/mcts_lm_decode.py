"""MCTS-guided LM decoding — the paper's pipeline searching token continuations.

A small randomly-initialized SmolLM-family model serves as the Playout
evaluator; each emitted token is chosen by a pipelined search over the top-A
continuations (PUCT priors from the policy logits).

  PYTHONPATH=src python examples/mcts_lm_decode.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.base import count_params, get_family
from repro.serving.mcts_decode import MCTSDecodeConfig, mcts_decode


def main():
    cfg = get_smoke_config("smollm-135m")
    fam = get_family(cfg)
    params = fam.init(cfg, jax.random.key(0))
    print(f"policy LM: {cfg.name}, {count_params(params):,} params")

    prompt = np.array([7, 3, 11, 19], dtype=np.int32)
    dcfg = MCTSDecodeConfig(num_actions=4, budget=24, lanes=4,
                            search_depth=5, rollout_len=3)
    t0 = time.time()
    toks = mcts_decode(cfg, params, prompt, n_tokens=6, dcfg=dcfg)
    dt = time.time() - t0

    # greedy baseline for comparison
    import jax.numpy as jnp
    seq = jnp.asarray(prompt)[None]
    greedy = []
    for _ in range(6):
        lg = fam.logits_fn(cfg, params, seq)
        t = int(jnp.argmax(lg[0, -1]))
        greedy.append(t)
        seq = jnp.concatenate([seq, jnp.asarray([[t]], jnp.int32)], 1)

    print(f"prompt        : {prompt.tolist()}")
    print(f"mcts decode   : {toks}   ({6 * dcfg.budget} playouts, {dt:.1f}s)")
    print(f"greedy decode : {greedy}")


if __name__ == "__main__":
    main()
