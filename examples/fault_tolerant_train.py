"""Fault-tolerance demo: a training run is killed mid-flight (injected
failure), restarted from the latest async checkpoint, and finishes with the
SAME final loss as an unbroken run — the restart consumes exactly the data
stream the lost run would have (deterministic (seed, step) batches).

  PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import tempfile

import jax

from repro.configs import get_smoke_config
from repro.data import DataConfig, make_batch_iterator
from repro.launch.steps import make_train_step
from repro.models.base import get_family
from repro.optim import adamw
from repro.optim.schedules import cosine
from repro.runtime.ft import FTConfig, TrainerLoop, run_with_restarts

STEPS = 40


def make_factory(ckpt_dir, fail_at=None):
    cfg = get_smoke_config("smollm-135m")
    fam = get_family(cfg)
    opt = adamw()
    step_fn = jax.jit(make_train_step(cfg, opt, cosine(1e-3, 2, STEPS)))
    params = fam.init(cfg, jax.random.key(0))
    builds = {"n": 0}

    def factory():
        builds["n"] += 1
        ft = FTConfig(ckpt_dir=ckpt_dir, ckpt_every=10,
                      fail_at_step=fail_at if builds["n"] == 1 else None)
        return TrainerLoop(
            step_fn, params, opt.init(params),
            lambda start: make_batch_iterator(
                cfg, DataConfig(seed=0, batch_size=4, seq_len=32), start), ft)
    return factory


def main():
    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        print(f"run A: injected process death at step 25 (checkpoint every 10)")
        out = run_with_restarts(make_factory(d1, fail_at=25), STEPS)
        print(f"  -> finished at step {out['step']} after {out['restarts']} restart(s), "
              f"final loss {out['losses'][-1]:.6f}")
        print("run B: unbroken reference")
        ref = make_factory(d2)().run(STEPS)
        print(f"  -> final loss {ref['losses'][-1]:.6f}")
        delta = abs(out["losses"][-1] - ref["losses"][-1])
        print(f"loss delta: {delta:.2e}  (restart == unbroken: {delta < 1e-5})")


if __name__ == "__main__":
    main()
