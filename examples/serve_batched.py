"""Continuous-batching serving example: a pool of requests streams through
the engine's prefill/decode interleave at fixed decode batch.

  PYTHONPATH=src python examples/serve_batched.py
"""
import subprocess
import sys


def main():
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--arch", "qwen2-0.5b", "--smoke",
           "--requests", "10", "--max-new", "12", "--max-batch", "4"]
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
