"""End-to-end training driver example: train a reduced SmolLM for a few
hundred steps with the full substrate (data pipeline, AdamW + cosine,
async checkpoints, fault-tolerant loop) and show loss goes down.

  PYTHONPATH=src python examples/train_tiny.py [--steps 300]
"""
import argparse
import subprocess
import sys
import tempfile


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    with tempfile.TemporaryDirectory() as d:
        cmd = [sys.executable, "-m", "repro.launch.train",
               "--arch", "smollm-135m", "--smoke",
               "--steps", str(args.steps), "--batch", "8", "--seq", "64",
               "--ckpt-dir", d, "--ckpt-every", "100"]
        raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
