"""Quickstart: pipelined MCTS on a synthetic P-game tree.

Runs the paper's linear pipeline (lanes=1) and nonlinear pipeline (lanes=8)
against the sequential baseline at equal budget, and prints strength vs the
exact enumeration oracle plus the in-flight duplicate rate (search overhead).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core.domains.pgame import PGameDomain, enumerate_root_values, optimal_root_action
from repro.core.pipeline import PipelineConfig, run_pipeline
from repro.core.sequential import run_sequential
from repro.core.stages import SearchParams
from repro.core.tree import root_action_by_visits


def main():
    dom = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=3)
    print("exact root values:", [f"{v:.3f}" for v in enumerate_root_values(dom)])
    opt = optimal_root_action(dom)
    print(f"optimal root action: {opt}\n")

    sp = SearchParams(cp=0.7, max_depth=6)
    budget = 256

    tree, _ = jax.jit(lambda r: run_sequential(dom, sp, budget, r))(jax.random.key(0))
    print(f"sequential      : action={int(root_action_by_visits(tree))} "
          f"(budget {budget})")

    for lanes in (1, 8):
        cfg = PipelineConfig(budget=budget, lanes=lanes, params=sp)
        tree, stats = jax.jit(lambda r: run_pipeline(dom, cfg, r))(jax.random.key(0))
        kind = "linear   " if lanes == 1 else "nonlinear"
        print(f"pipeline {kind}: action={int(root_action_by_visits(tree))} "
              f"playouts={int(stats['playouts'])} "
              f"duplicates={int(stats['duplicates'])} "
              f"occupancy={float(stats['mean_occupancy']):.2f}")


if __name__ == "__main__":
    main()
