"""Quickstart: the unified search API on a synthetic P-game tree.

Runs every registered strategy (sequential baseline, the paper's §IV
baselines, and the paper's pipelined MCTS) at equal budget through ONE entry
point — ``repro.search.search`` — and prints the recommended action vs the
exact enumeration oracle plus the common stats schema.  Finishes with a
batched multi-root search (``search_batch``): 4 independent searches in one
device program.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core.domains.pgame import PGameDomain, enumerate_root_values, optimal_root_action
from repro.search import SearchConfig, SearchParams, search, search_batch


def main():
    dom = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=3)
    print("exact root values:", [f"{v:.3f}" for v in enumerate_root_values(dom)])
    opt = optimal_root_action(dom)
    print(f"optimal root action: {opt}\n")

    sp = SearchParams(cp=0.7, max_depth=6)
    budget = 256

    for method, lanes in (("sequential", 1), ("root", 4), ("leaf", 4),
                          ("tree", 8), ("pipeline", 1), ("pipeline", 8)):
        cfg = SearchConfig(method=method, budget=budget, lanes=lanes, params=sp)
        res = jax.jit(lambda r: search(dom, cfg, r))(jax.random.key(0))
        extra = ""
        if method == "pipeline":
            kind = "linear" if lanes == 1 else "nonlinear"
            extra = (f" occupancy={float(res.extras['mean_occupancy']):.2f}"
                     f" ({kind})")
        print(f"{method:<10} lanes={lanes:<2}: action={int(res.best_action)} "
              f"playouts={int(res.stats['playouts'])} "
              f"duplicates={int(res.stats['duplicates'])}"
              f"{extra}")

    # batched multi-root search: 4 independent pipelines, one XLA program
    cfg = SearchConfig(method="pipeline", budget=budget, lanes=8, params=sp,
                       keep_tree=False)
    bres = search_batch([dom] * 4, cfg, jax.random.key(1))
    print(f"\nsearch_batch(B=4): actions="
          f"{[int(a) for a in bres.best_action]} "
          f"playouts={[int(p) for p in bres.stats['playouts']]}")


if __name__ == "__main__":
    main()
