"""The tools/api_surface.py checker: current tree is clean; a smuggled
run_* entry point outside repro/search is caught; a dict-style tree plane
subscript outside core/arena.py is caught (DESIGN.md §14)."""
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import api_surface  # noqa: E402


def test_current_tree_is_clean():
    assert api_surface.check(REPO / "src") == []


def test_detects_new_entry_point(tmp_path):
    mod = tmp_path / "repro" / "core" / "rogue.py"
    mod.parent.mkdir(parents=True)
    mod.write_text("def run_rogue_search(domain):\n    pass\n")
    [(rel, msg)] = api_surface.check(tmp_path)
    assert rel == "repro/core/rogue.py" and "run_rogue_search" in msg


def test_search_package_is_exempt(tmp_path):
    mod = tmp_path / "repro" / "search" / "extra.py"
    mod.parent.mkdir(parents=True)
    mod.write_text("def run_new_strategy(domain):\n    pass\n")
    assert api_surface.check(tmp_path) == []


def test_detects_dict_style_plane_access(tmp_path):
    mod = tmp_path / "repro" / "search" / "sneaky.py"
    mod.parent.mkdir(parents=True)
    mod.write_text("def peek(tree):\n    return tree['visits'].sum()\n")
    [(rel, msg)] = api_surface.check(tmp_path)
    assert rel == "repro/search/sneaky.py"
    assert "'visits'" in msg and "TreeArena" in msg


def test_plane_access_allowed_in_arena_and_dict_literals(tmp_path):
    arena = tmp_path / "repro" / "core" / "arena.py"
    arena.parent.mkdir(parents=True)
    arena.write_text("def shim(self, k):\n    return planes['vloss']\n")
    ok = tmp_path / "repro" / "core" / "other.py"
    # dict literal keys and buffer keys outside the plane set are fine
    ok.write_text("d = {'prior': 1}\nx = sel['leaf']\nv = po['value']\n")
    assert api_surface.check(tmp_path) == []
