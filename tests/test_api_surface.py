"""The tools/api_surface.py checker: current tree is clean; a smuggled
run_* entry point outside repro/search is caught."""
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import api_surface  # noqa: E402


def test_current_tree_is_clean():
    assert api_surface.check(REPO / "src") == []


def test_detects_new_entry_point(tmp_path):
    mod = tmp_path / "repro" / "core" / "rogue.py"
    mod.parent.mkdir(parents=True)
    mod.write_text("def run_rogue_search(domain):\n    pass\n")
    assert api_surface.check(tmp_path) == [("repro/core/rogue.py",
                                            "run_rogue_search")]


def test_search_package_is_exempt(tmp_path):
    mod = tmp_path / "repro" / "search" / "extra.py"
    mod.parent.mkdir(parents=True)
    mod.write_text("def run_new_strategy(domain):\n    pass\n")
    assert api_surface.check(tmp_path) == []
