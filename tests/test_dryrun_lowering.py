"""Integration test of the dry-run path itself: lower + compile real cells
on a small forced-device mesh (subprocess; the production 512-device sweep
lives in experiments/dryrun_*.json)."""
import subprocess
import sys
import textwrap


def _run(code: str, devices: int = 8):
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src",
             "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
             "PATH": "/usr/bin:/bin:/usr/local/bin", "HOME": "/root"})
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    return r.stdout


def test_lower_compile_train_and_decode_cells():
    out = _run("""
        import jax
        from repro.parallel.compat import make_mesh
        from repro.launch.dryrun import lower_cell
        from repro.launch import hlo_analysis
        mesh = make_mesh((2, 4), ("data", "model"))
        for arch, shape in [("smollm-135m", "train_4k"),
                            ("rwkv6-1.6b", "decode_32k")]:
            lowered, meta = lower_cell(arch, shape, mesh)
            compiled = lowered.compile()
            m = compiled.memory_analysis()
            costs = hlo_analysis.analyze_module(compiled.as_text(), 8)
            assert costs.flops > 0
            assert m.argument_size_in_bytes > 0
            print("OK", arch, shape, f"{costs.flops:.2e}")
    """)
    assert out.count("OK") == 2


def test_multipod_axis_shards_batch():
    """The pod axis must actually participate in the batch sharding."""
    out = _run("""
        import jax
        from repro.parallel.compat import make_mesh
        from repro.launch.dryrun import lower_cell
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        lowered, _ = lower_cell("stablelm-3b", "decode_32k", mesh)
        txt = lowered.as_text()
        assert "num_partitions = 8" in txt or "num_partitions=8" in txt
        # pod axis present in the sdy mesh (GSPMD lowering on old jax has no
        # axis names in the IR text, so only check under the shardy dialect)
        if "sdy.mesh" in txt:
            assert '"pod"' in txt
        print("OK")
    """)
    assert "OK" in out
