"""Deterministic fault-injection suite for the elastic search driver
(DESIGN.md §13).

Every scenario is driven through ``FTSearchConfig``'s injection knobs
(``kill_host_at_root`` / ``stall_host_at_root``) and checked against the
ORACLE: the uninterrupted ``search_batch`` run.  The paper's root
parallelism makes the invariant exact — each root's result depends only on
its own (domain, key), so requeue + merge must be bit-for-bit identical to
a run where nothing failed.
"""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.domains.pgame import PGameDomain
from repro.search import (ElasticSearchDriver, FTSearchConfig, SearchConfig,
                          SearchParams, STATS_KEYS, ft_search_batch,
                          search_batch)

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=3)
SP = SearchParams(cp=0.7, max_depth=6)
METHODS = ("sequential", "root", "leaf", "tree", "pipeline")
B = 6
FAST = dict(watchdog_s=0.05)     # watchdog small so stall tests stay quick

_baselines = {}


def _cfg(method):
    return SearchConfig(method=method, budget=32, lanes=4, params=SP,
                        keep_tree=False)


def _baseline(method):
    if method not in _baselines:
        _baselines[method] = search_batch(
            [DOM] * B, _cfg(method), jax.random.key(7), mesh=False)
    return _baselines[method]


def _assert_bitwise(res, ref):
    np.testing.assert_array_equal(np.asarray(res.action_visits),
                                  np.asarray(ref.action_visits))
    np.testing.assert_array_equal(np.asarray(res.action_value),
                                  np.asarray(ref.action_value))
    np.testing.assert_array_equal(np.asarray(res.best_action),
                                  np.asarray(ref.best_action))
    for k in STATS_KEYS:
        np.testing.assert_array_equal(np.asarray(res.stats[k]),
                                      np.asarray(ref.stats[k]))


@pytest.mark.parametrize("method", METHODS)
def test_killed_host_merges_bitwise(method):
    """Kill the host that owns root 4 as it launches: the merged result is
    bit-for-bit the uninterrupted run, only the victim's in-flight roots ran
    twice, and the dead host stays dead."""
    drv = ElasticSearchDriver(
        [DOM] * B, _cfg(method), jax.random.key(7),
        FTSearchConfig(hosts=3, chunk=1, kill_host_at_root=4, **FAST))
    res = drv.run()
    _assert_bitwise(res, _baseline(method))
    assert drv.report.lost_hosts == [2]          # blocks of 2: root 4 -> host 2
    assert drv.report.requeued == [4]
    runs = drv.report.runs
    assert runs[4] == 2 and all(runs[i] == 1 for i in range(B) if i != 4)
    assert drv.alive == [True, True, False]


@pytest.mark.parametrize("method", METHODS)
def test_stalled_host_merges_bitwise(method):
    """A host hung past the watchdog is declared lost by the Heartbeat and
    treated exactly like a kill: requeue its in-flight chunk, same merge."""
    drv = ElasticSearchDriver(
        [DOM] * B, _cfg(method), jax.random.key(7),
        FTSearchConfig(hosts=2, chunk=2, stall_host_at_root=1, **FAST))
    res = drv.run()
    _assert_bitwise(res, _baseline(method))
    assert drv.report.lost_hosts == [0]
    assert sorted(drv.report.requeued) == [0, 1]  # the in-flight chunk
    runs = drv.report.runs
    assert runs[0] == 2 and runs[1] == 2
    assert all(runs[i] == 1 for i in range(2, B))


def test_requeued_roots_run_at_most_once_extra():
    """Whole-queue chunks: a kill requeues the host's entire in-flight set;
    every victim runs exactly twice, everything else exactly once."""
    drv = ElasticSearchDriver(
        [DOM] * B, _cfg("pipeline"), jax.random.key(7),
        FTSearchConfig(hosts=2, chunk=0, kill_host_at_root=3, **FAST))
    res = drv.run()
    _assert_bitwise(res, _baseline("pipeline"))
    victims = set(drv.report.requeued)
    assert victims == {3, 4, 5}                  # host 1's whole queue
    assert all(drv.report.runs[i] == (2 if i in victims else 1)
               for i in range(B))
    assert int(drv.report.runs.max()) == 2


def test_failure_point_never_reached_is_noop():
    """A failure configured past the last root is never triggered: no lost
    hosts, no requeues, every root runs exactly once."""
    drv = ElasticSearchDriver(
        [DOM] * B, _cfg("sequential"), jax.random.key(7),
        FTSearchConfig(hosts=2, kill_host_at_root=B + 17, **FAST))
    res = drv.run()
    _assert_bitwise(res, _baseline("sequential"))
    assert drv.report.lost_hosts == [] and drv.report.requeued == []
    assert all(drv.report.runs == 1)


def test_failure_after_last_commit_is_noop(tmp_path):
    """Once every root is committed, a configured failure can never fire: a
    restarted driver with a kill injection resumes from the checkpoint,
    launches nothing, loses nothing, and returns the same merged result."""
    ckpt = dict(ckpt_dir=str(tmp_path), **FAST)
    first = ElasticSearchDriver([DOM] * B, _cfg("tree"), jax.random.key(7),
                                FTSearchConfig(hosts=2, **ckpt))
    res1 = first.run()
    again = ElasticSearchDriver(
        [DOM] * B, _cfg("tree"), jax.random.key(7),
        FTSearchConfig(hosts=2, kill_host_at_root=2, **ckpt))
    res2 = again.run()
    _assert_bitwise(res2, res1)
    _assert_bitwise(res2, _baseline("tree"))
    assert again.report.resumed == list(range(B))
    assert all(again.report.runs == 0)
    assert again.report.lost_hosts == [] and again.report.requeued == []


def test_driver_restart_resumes_from_committed_roots(tmp_path):
    """A driver restart (fresh process image, same ckpt_dir) re-runs only the
    uncommitted roots and merges to the uninterrupted result."""
    ft = FTSearchConfig(hosts=2, chunk=2, ckpt_dir=str(tmp_path), **FAST)
    d1 = ElasticSearchDriver([DOM] * B, _cfg("pipeline"), jax.random.key(7),
                             ft)
    assert d1.run(max_rounds=1) is None          # "crash" after one round
    committed = set(np.nonzero(d1._done)[0].tolist())
    assert 0 < len(committed) < B
    d2 = ElasticSearchDriver([DOM] * B, _cfg("pipeline"), jax.random.key(7),
                             ft)
    res = d2.run()
    _assert_bitwise(res, _baseline("pipeline"))
    assert set(d2.report.resumed) == committed
    assert all(d2.report.runs[i] == 0 for i in committed)
    assert all(d2.report.runs[i] == 1 for i in range(B)
               if i not in committed)


def test_losing_every_host_raises():
    with pytest.raises(RuntimeError, match="hosts lost"):
        ft_search_batch([DOM] * 2, _cfg("sequential"), jax.random.key(7),
                        ft=FTSearchConfig(hosts=1, kill_host_at_root=0,
                                          **FAST))


def test_varying_domains_and_stats_survive_failure():
    """Per-root varying fields ride through requeue/merge unchanged, and the
    full stats schema matches the oracle."""
    doms = [PGameDomain(num_actions=4, game_depth=6, binary_reward=True,
                        seed=3, threshold=t)
            for t in (0.3, 0.4, 0.5, 0.6, 0.7)]
    cfg = _cfg("root")
    rng = jax.random.key(11)
    base = search_batch(doms, cfg, rng, mesh=False)
    drv = ElasticSearchDriver(
        doms, cfg, rng,
        FTSearchConfig(hosts=2, chunk=2, kill_host_at_root=3, **FAST))
    _assert_bitwise(drv.run(), base)
    assert sorted(drv.report.requeued) == [3, 4]  # the in-flight chunk


# -- serving: the shrink event goes through the PR 6 scheduler --------------
def test_engine_shrink_evicts_and_requeues_keeping_committed():
    from repro.models.base import ModelConfig, get_family
    from repro.serving import MCTSDecodeConfig
    from repro.serving.engine import EngineConfig, ServingEngine
    from repro.serving.scheduler import Request

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32", ce_chunk=8, remat=False)
    params = get_family(cfg).init(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=4, max_seq=32, decode="mcts",
        mcts=MCTSDecodeConfig(num_actions=3, budget=6, lanes=2,
                              search_depth=2, rollout_len=1), mesh=False))
    for u in range(5):
        eng.submit(Request(uid=u, prompt=np.array([1 + u, 2], np.int32),
                           max_new_tokens=6))
    eng.step()
    eng.step()
    victims = {i: eng.sched.request(i) for i in (0, 1)
               if eng.sched.is_live(i)}
    committed = {i: list(r.out_tokens) for i, r in victims.items()}
    evicted = eng.shrink([0, 1])                 # a lost host owned slots 0-1
    assert sorted(evicted) == sorted(victims)
    assert set(eng.sched.live()) <= {2, 3}       # re-placed onto survivors
    assert eng.sched.is_disabled(0) and eng.sched.is_disabled(1)
    out = eng.run_until_drained()
    for i, req in victims.items():
        assert req.done and len(req.out_tokens) == 6
        assert req.out_tokens[:len(committed[i])] == committed[i]
    assert int(out["stats"]["serving/preemptions"]) >= len(victims)
    # the pool never admits to a disabled slot again
    assert all(not eng.sched.is_live(s) for s in (0, 1))


def test_engine_shrink_to_zero_slots_rejected():
    from repro.models.base import ModelConfig, get_family
    from repro.serving.engine import EngineConfig, ServingEngine

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32", ce_chunk=8, remat=False)
    params = get_family(cfg).init(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=2, max_seq=16))
    with pytest.raises(ValueError, match="survive"):
        eng.shrink([0, 1])
    eng.shrink([0])
    with pytest.raises(ValueError, match="survive"):
        eng.shrink([1])


# -- always-run: the sharded elastic path on 8 fake devices -----------------
def test_ft_mesh_shrink_subprocess_8dev():
    """Single-device sessions: kill a host owning half an 8-device mesh; the
    survivors' shrunken world still merges bit-for-bit (the pattern of
    tests/test_sharding.py)."""
    code = textwrap.dedent("""
        import jax, numpy as np
        from repro.core.domains.pgame import PGameDomain
        from repro.launch.mesh import make_search_mesh
        from repro.search import (ElasticSearchDriver, FTSearchConfig,
                                  SearchConfig, SearchParams, search_batch)
        assert jax.device_count() == 8
        DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False,
                          seed=3)
        cfg = SearchConfig(method="pipeline", budget=32, lanes=4,
                           params=SearchParams(cp=0.7, max_depth=6),
                           keep_tree=False)
        rng = jax.random.key(42)
        base = search_batch([DOM] * 10, cfg, rng, mesh=False)
        drv = ElasticSearchDriver(
            [DOM] * 10, cfg, rng,
            FTSearchConfig(hosts=2, chunk=4, watchdog_s=0.1,
                           kill_host_at_root=6),
            mesh=make_search_mesh())
        res = drv.run()
        np.testing.assert_array_equal(np.asarray(res.action_visits),
                                      np.asarray(base.action_visits))
        np.testing.assert_array_equal(np.asarray(res.action_value),
                                      np.asarray(base.action_value))
        assert drv.report.lost_hosts == [1]
        # host 1's devices are gone; the survivor owns the shrunken world
        worlds = [len(d or []) for d in drv._host_devices]
        assert worlds == [4, 0], worlds
        print("OK")
    """)
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "JAX_PLATFORMS": "cpu",
             "PATH": "/usr/bin:/bin:/usr/local/bin", "HOME": "/root"})
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "OK" in r.stdout
