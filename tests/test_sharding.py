"""Sharded batched multi-root search (DESIGN.md §9): ``shard_search_batch``
reproduces the single-device vmap semantics bit-for-bit, including when B is
not a multiple of the device count (padding contract).

The in-process tests need a multi-device runtime and run in the CI
multi-device job (8 forced host devices); on a single-device session one
subprocess test re-runs the core parity checks on 8 fake devices so tier-1
always exercises the path.
"""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.domains.pgame import PGameDomain
from repro.search import (STATS_KEYS, SearchConfig, SearchParams, search,
                          search_batch, shard_search_batch)

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=3)
SP = SearchParams(cp=0.7, max_depth=6)
METHODS = ("sequential", "root", "leaf", "tree", "pipeline")

multi = jax.device_count() >= 2
needs_mesh = pytest.mark.skipif(
    not multi, reason="needs >1 device (run in the CI multi-device job; the "
    "subprocess test below covers single-device sessions)")


def _vmap_ref(domains, cfg, rng):
    """The documented per-root reference: element i ==
    search(domains[i], cfg, jax.random.split(rng, B)[i])."""
    keys = jax.random.split(rng, len(domains))
    return [search(d, cfg, k) for d, k in zip(domains, keys)]


def _assert_matches(res, refs):
    assert res.action_visits.shape == (len(refs), DOM.num_actions)
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(np.asarray(res.action_visits[i]),
                                      np.asarray(ref.action_visits))
        np.testing.assert_allclose(np.asarray(res.action_value[i]),
                                   np.asarray(ref.action_value), rtol=1e-5)
        assert int(res.best_action[i]) == int(ref.best_action)
        for k in STATS_KEYS:
            assert int(res.stats[k][i]) == int(ref.stats[k])


@needs_mesh
@pytest.mark.parametrize("method", METHODS)
def test_sharded_parity_all_strategies(method):
    """Device-count-divisible B: every strategy matches the vmap semantics
    bit-for-bit on action_visits and the whole stats schema."""
    cfg = SearchConfig(method=method, budget=32, lanes=4, params=SP,
                       keep_tree=False)
    rng = jax.random.key(7)
    b = jax.device_count()
    res = shard_search_batch([DOM] * b, cfg, rng)
    _assert_matches(res, _vmap_ref([DOM] * b, cfg, rng))


@needs_mesh
@pytest.mark.parametrize("b", (1, 5, 11))
def test_sharded_parity_with_padding(b):
    """B not divisible by the device count: rows are padded to the mesh and
    the pad sliced off — results identical to the unpadded contract."""
    cfg = SearchConfig(method="pipeline", budget=32, lanes=4, params=SP,
                       keep_tree=False)
    rng = jax.random.key(1)
    res = shard_search_batch([DOM] * b, cfg, rng)
    _assert_matches(res, _vmap_ref([DOM] * b, cfg, rng))


@needs_mesh
def test_sharded_parity_varying_fields():
    """The stacked-varying-fields path shards too (each root its own
    threshold), with the same per-element parity."""
    doms = [PGameDomain(num_actions=4, game_depth=6, binary_reward=True,
                        seed=3, threshold=t) for t in (0.3, 0.45, 0.6)]
    cfg = SearchConfig(method="sequential", budget=32, params=SP,
                       keep_tree=False)
    rng = jax.random.key(2)
    res = shard_search_batch(doms, cfg, rng)
    _assert_matches(res, _vmap_ref(doms, cfg, rng))


@needs_mesh
def test_search_batch_auto_shards_and_matches():
    """With >1 visible device, plain ``search_batch`` auto-shards (and the
    explicit mesh= / mesh=False spellings agree with it)."""
    from repro.launch.mesh import make_search_mesh
    cfg = SearchConfig(method="tree", budget=32, lanes=4, params=SP,
                       keep_tree=False)
    rng = jax.random.key(3)
    doms = [DOM] * 6
    auto = search_batch(doms, cfg, rng)
    _assert_matches(auto, _vmap_ref(doms, cfg, rng))
    explicit = search_batch(doms, cfg, rng, mesh=make_search_mesh())
    forced_vmap = search_batch(doms, cfg, rng, mesh=False)
    for other in (explicit, forced_vmap):
        np.testing.assert_array_equal(np.asarray(auto.action_visits),
                                      np.asarray(other.action_visits))


@needs_mesh
def test_sharded_keep_tree_and_output_sharding():
    """keep_tree=True round-trips the full tree pytree, and outputs really
    are split along the mesh's batch axis."""
    cfg = SearchConfig(method="sequential", budget=16, params=SP)
    b = jax.device_count()
    res = shard_search_batch([DOM] * b, cfg, jax.random.key(0))
    assert res.tree is not None
    assert res.tree["visits"].shape[0] == b
    spec = res.action_visits.sharding.spec
    assert tuple(spec)[:1] == ("batch",)


@needs_mesh
def test_sharded_searcher_spreads_slots():
    """The serving searcher pads the slot batch to the mesh and returns one
    token per real slot."""
    import jax.numpy as jnp

    from repro.models.base import ModelConfig, get_family
    from repro.serving import MCTSDecodeConfig
    from repro.serving.mcts_decode import make_batched_searcher

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32", ce_chunk=8, remat=False)
    params = get_family(cfg).init(cfg, jax.random.key(0))
    dcfg = MCTSDecodeConfig(num_actions=3, budget=6, lanes=2, search_depth=2,
                            rollout_len=1)
    batch = 3                                  # pads to the device count
    searcher = make_batched_searcher(cfg, params, dcfg, batch=batch)
    buf = jnp.zeros((batch, 8), jnp.int32).at[:, :2].set(
        jnp.array([[1, 2], [3, 4], [5, 6]], jnp.int32))
    toks = searcher(buf, jnp.full((batch,), 2, jnp.int32), jax.random.key(1))
    assert toks.shape == (batch,)
    assert all(0 <= int(t) < cfg.vocab_size for t in toks)


def test_shard_parity_subprocess_8dev():
    """Single-device sessions: the same parity checks on 8 forced host
    devices (the pattern of tests/test_distributed.py)."""
    code = textwrap.dedent("""
        import jax, numpy as np
        from repro.core.domains.pgame import PGameDomain
        from repro.search import (SearchConfig, SearchParams, search,
                                  search_batch, shard_search_batch)
        DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False,
                          seed=3)
        SP = SearchParams(cp=0.7, max_depth=6)
        rng = jax.random.key(42)
        assert jax.device_count() == 8
        for method, b in (("sequential", 8), ("pipeline", 5)):
            cfg = SearchConfig(method=method, budget=32, lanes=4, params=SP,
                               keep_tree=False)
            res = shard_search_batch([DOM] * b, cfg, rng)
            keys = jax.random.split(rng, b)
            for i in range(b):
                ind = search(DOM, cfg, keys[i])
                np.testing.assert_array_equal(
                    np.asarray(res.action_visits[i]),
                    np.asarray(ind.action_visits))
        # auto-sharding spelling agrees
        cfg = SearchConfig(method="pipeline", budget=32, lanes=4, params=SP,
                           keep_tree=False)
        auto = search_batch([DOM] * 5, cfg, rng)
        shard = shard_search_batch([DOM] * 5, cfg, rng)
        np.testing.assert_array_equal(np.asarray(auto.action_visits),
                                      np.asarray(shard.action_visits))
        print("OK")
    """)
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "JAX_PLATFORMS": "cpu",
             "PATH": "/usr/bin:/bin:/usr/local/bin", "HOME": "/root"})
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "OK" in r.stdout
