"""Lockstep (depth-major) vs scan (lane-major) wave selection (DESIGN.md §11).

Contracts under test:

* ``wave_select="scan"`` is the pre-existing path, untouched — and at
  ``lanes == 1`` the lockstep path is bit-for-bit identical to it (the
  exact-parity escape hatch of ISSUE 5).
* At ``lanes > 1`` the two paths differ per seed (per-level vs per-lane
  virtual loss) but agree in distribution: aggregate root-visit fractions
  stay within tolerance and both recommend the same aggregate best action.
* Tree invariants (vloss drained, visit flow) hold for lockstep runs.
* The lockstep Select stage issues ONE batched ``[lanes, A]`` UCT call per
  tree level (the scan path issues single-row calls) — asserted via a
  trace-time hook on ``repro.core.uct.uct_argmax``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stages as S
from repro.core import uct
from repro.core.domains.pgame import PGameDomain, optimal_root_action
from repro.core.tree import check_consistency
from repro.search import SearchConfig, SearchParams, search

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=3)
METHODS = ("tree", "pipeline")


def _cfg(method, ws, lanes, budget, **kw):
    sp = SearchParams(cp=0.7, max_depth=6, wave_select=ws)
    return SearchConfig(method=method, budget=budget, lanes=lanes,
                        params=sp, **kw)


def _run(method, ws, lanes, budget=128, seed=0, **kw):
    cfg = _cfg(method, ws, lanes, budget, **kw)
    return jax.jit(lambda r: search(DOM, cfg, r))(jax.random.key(seed))


# ---------------------------------------------------------------------------
# knob resolution
# ---------------------------------------------------------------------------
def test_wave_select_resolution():
    assert SearchParams().resolved_wave_select == "scan"
    # deprecated boolean forwards into the consolidated kernels knob; with
    # Pallas kernels the auto wave_select is the fused megakernel (§14)
    with pytest.warns(DeprecationWarning):
        sp = SearchParams(use_pallas=True)
    assert sp.resolved_kernels == "pallas"
    assert sp.resolved_wave_select == "mega"
    with pytest.warns(DeprecationWarning):
        sp = SearchParams(wave_select="scan", use_pallas=True)
    assert sp.resolved_wave_select == "scan"
    assert SearchParams(wave_select="lockstep").resolved_wave_select == "lockstep"
    assert SearchParams(kernels="pallas").resolved_wave_select == "mega"
    assert SearchParams(kernels="ref").resolved_wave_select == "scan"
    # explicit kernels wins over the deprecated boolean
    with pytest.warns(DeprecationWarning):
        sp = SearchParams(kernels="ref", use_pallas=True)
    assert sp.resolved_kernels == "ref"
    with pytest.raises(ValueError, match="wave_select"):
        _ = SearchParams(wave_select="nope").resolved_wave_select
    with pytest.raises(ValueError, match="kernels"):
        _ = SearchParams(kernels="nope").resolved_kernels


# ---------------------------------------------------------------------------
# exact parity at lanes=1 (and scan reproduces the default path bit-for-bit)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("seed", (0, 1))
def test_lockstep_exact_parity_at_lanes1(method, seed):
    a = _run(method, "scan", 1, seed=seed)
    b = _run(method, "lockstep", 1, seed=seed)
    np.testing.assert_array_equal(np.asarray(a.action_visits),
                                  np.asarray(b.action_visits))
    np.testing.assert_array_equal(np.asarray(a.action_value),
                                  np.asarray(b.action_value))
    np.testing.assert_array_equal(np.asarray(a.tree["visits"]),
                                  np.asarray(b.tree["visits"]))
    np.testing.assert_array_equal(np.asarray(a.tree["children"]),
                                  np.asarray(b.tree["children"]))
    for k in a.stats:
        assert int(a.stats[k]) == int(b.stats[k]), k


@pytest.mark.parametrize("method", METHODS)
def test_scan_mode_is_the_default_path(method):
    """``wave_select="scan"`` and the default params produce identical
    results — the escape hatch IS the pre-PR behaviour."""
    a = _run(method, "auto", 4, seed=2)        # use_pallas=False -> scan
    b = _run(method, "scan", 4, seed=2)
    np.testing.assert_array_equal(np.asarray(a.action_visits),
                                  np.asarray(b.action_visits))
    np.testing.assert_array_equal(np.asarray(a.tree["visits"]),
                                  np.asarray(b.tree["visits"]))


# ---------------------------------------------------------------------------
# statistical parity + invariants at wave sizes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", METHODS)
def test_lockstep_statistical_parity(method):
    """Aggregate root-visit fractions of lockstep and scan agree within
    tolerance at a converged budget, and both point at the same aggregate
    best action (distribution-level equivalence, not per-seed equality)."""
    seeds, budget, lanes = 6, 512, 8
    agg = {}
    for ws in ("scan", "lockstep"):
        cfg = _cfg(method, ws, lanes, budget, keep_tree=False)
        fn = jax.jit(lambda r: search(DOM, cfg, r).action_visits)
        v = np.zeros(DOM.num_actions)
        for s in range(seeds):
            v += np.asarray(fn(jax.random.key(s)))
        agg[ws] = v / v.sum()
    l1 = float(np.abs(agg["scan"] - agg["lockstep"]).sum())
    assert l1 < 0.25, (agg, l1)
    assert int(np.argmax(agg["scan"])) == int(np.argmax(agg["lockstep"]))
    assert int(np.argmax(agg["lockstep"])) == optimal_root_action(DOM)


@pytest.mark.parametrize("method", METHODS)
def test_wu_lockstep_statistical_parity_with_scan(method):
    """WU-UCT in-flight statistics (vl_mode="wu", DESIGN.md §15) change the
    per-seed trajectories but not the distribution: aggregate root-visit
    fractions of wu-lockstep at lanes=8 agree with the scan baseline within
    tolerance, and both find the true optimum."""
    seeds, budget, lanes = 6, 512, 8
    agg = {}
    for name, ws, vm in (("scan", "scan", "loss"),
                         ("wu", "lockstep", "wu")):
        sp = SearchParams(cp=0.7, max_depth=6, wave_select=ws, vl_mode=vm)
        cfg = SearchConfig(method=method, budget=budget, lanes=lanes,
                           params=sp, keep_tree=False)
        fn = jax.jit(lambda r: search(DOM, cfg, r).action_visits)
        v = np.zeros(DOM.num_actions)
        for s in range(seeds):
            v += np.asarray(fn(jax.random.key(s)))
        agg[name] = v / v.sum()
    l1 = float(np.abs(agg["scan"] - agg["wu"]).sum())
    assert l1 < 0.25, (agg, l1)
    assert int(np.argmax(agg["wu"])) == int(np.argmax(agg["scan"]))
    assert int(np.argmax(agg["wu"])) == optimal_root_action(DOM)


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("lanes", (4, 8))
def test_lockstep_invariants(method, lanes):
    res = _run(method, "lockstep", lanes, budget=256)
    c = check_consistency(res.tree)
    assert c["vloss_drained"], c
    assert c["visit_flow"], c
    assert c["parents_valid"], c
    assert int(res.stats["playouts"]) == 256
    assert int(res.tree["visits"][0]) == 256


def test_lockstep_terminal_root_no_descent():
    """All-lanes-done edge: a root that is terminal (or unexpanded) ends the
    level loop immediately — every lane reports the root as its leaf."""
    dom = PGameDomain(num_actions=3, game_depth=0, seed=0)   # root terminal
    sp = SearchParams(cp=0.7, max_depth=4, wave_select="lockstep")
    from repro.core.tree import init_tree
    tree = init_tree(dom, 8)
    tree2, sel = S.select_wave(tree, sp, 4, jnp.asarray(True))
    assert np.asarray(sel["leaf"]).tolist() == [0, 0, 0, 0]
    assert np.asarray(sel["depth"]).tolist() == [0, 0, 0, 0]
    # root VL applied for every valid lane, nothing deeper
    assert int(tree2["vloss"][0]) == 4
    assert int(tree2["vloss"][1:].sum()) == 0


def test_lockstep_invalid_wave_leaves_tree_untouched():
    """A fully-masked wave (pipeline drain tick) must not write any VL."""
    sp = SearchParams(cp=0.7, max_depth=6, wave_select="lockstep")
    from repro.core.tree import init_tree
    tree = init_tree(DOM, 16)
    tree2, sel = S.select_wave(tree, sp, 4, jnp.asarray(False))
    assert int(tree2["vloss"].sum()) == 0
    assert not bool(np.asarray(sel["valid"]).any())
    assert bool((np.asarray(sel["path"]) == -1).all())


# ---------------------------------------------------------------------------
# the batched-launch contract: one [lanes, A] UCT call per tree level
# ---------------------------------------------------------------------------
def _spy_shapes(monkeypatch):
    shapes = []
    real = uct.uct_argmax

    def spy(child_n, *a, **kw):
        shapes.append(tuple(child_n.shape))
        return real(child_n, *a, **kw)

    monkeypatch.setattr(uct, "uct_argmax", spy)
    return shapes


def test_lockstep_issues_one_batched_call_per_level(monkeypatch):
    shapes = _spy_shapes(monkeypatch)
    cfg = _cfg("tree", "lockstep", 8, 64)
    jax.jit(lambda r: search(DOM, cfg, r).best_action)(jax.random.key(0))
    # the level loop has exactly ONE traced select call, batched over lanes
    assert shapes == [(8, DOM.num_actions)]


def test_scan_issues_single_row_calls(monkeypatch):
    shapes = _spy_shapes(monkeypatch)
    cfg = _cfg("tree", "scan", 8, 64)
    jax.jit(lambda r: search(DOM, cfg, r).best_action)(jax.random.key(0))
    # lane-major: the per-lane descent scores one node's children at a time
    assert shapes == [(DOM.num_actions,)]


# ---------------------------------------------------------------------------
# lockstep through the serving config
# ---------------------------------------------------------------------------
def test_mcts_decode_config_threads_wave_select():
    from repro.serving.mcts_decode import MCTSDecodeConfig
    scfg = MCTSDecodeConfig(wave_select="lockstep").search_config()
    assert scfg.params.resolved_wave_select == "lockstep"
    assert MCTSDecodeConfig().search_config().params.wave_select == "auto"


def test_mcts_decode_config_threads_vl_mode():
    from repro.serving.mcts_decode import MCTSDecodeConfig
    assert MCTSDecodeConfig(vl_mode="wu").search_config().params.vl_mode \
        == "wu"
    assert MCTSDecodeConfig().search_config().params.vl_mode == "loss"
