"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import schedule, uct
from repro.parallel.sharding import DEFAULT_RULES, resolve_spec

settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# schedule model properties (paper's pipeline arithmetic)
# ---------------------------------------------------------------------------
@given(n=st.integers(1, 64),
       costs=st.tuples(*[st.floats(0.25, 4.0) for _ in range(4)]),
       lanes=st.integers(1, 8))
def test_pipeline_never_slower_than_sequential(n, costs, lanes):
    p = schedule.pipeline_makespan(n, costs, lanes)
    s = schedule.sequential_makespan(n, costs)
    assert p <= s + 1e-9


@given(n=st.integers(2, 64),
       costs=st.tuples(*[st.floats(0.25, 4.0) for _ in range(4)]))
def test_pipeline_lower_bound_is_bottleneck(n, costs):
    """Makespan >= n / steady-state throughput (slowest-stage bound)."""
    p = schedule.pipeline_makespan(n, costs, lanes=1)
    bound = n * max(costs)
    assert p >= bound - 1e-9


@given(n=st.integers(1, 32),
       cp=st.floats(0.5, 4.0), lanes=st.integers(1, 8))
def test_lanes_saturate_at_playout_cost(n, cp, lanes):
    costs = (1.0, 1.0, cp, 1.0)
    t1 = schedule.pipeline_makespan(n, costs, lanes)
    t2 = schedule.pipeline_makespan(n, costs, lanes + 1)
    assert t2 <= t1 + 1e-9          # more lanes never hurts


# ---------------------------------------------------------------------------
# UCT scoring properties (paper eq. 1)
# ---------------------------------------------------------------------------
@given(st.integers(0, 2**31 - 1))
def test_uct_picks_unvisited_first(seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(2, 12)
    n = rng.integers(1, 50, a).astype(np.float32)
    unv = rng.integers(0, a)
    n[unv] = 0
    w = rng.normal(size=a).astype(np.float32) * 10
    s = uct.uct_scores(jnp.asarray(n), jnp.asarray(w), jnp.zeros(a),
                       jnp.asarray(n.sum()), 1.4)
    assert int(jnp.argmax(s)) == unv


@given(st.integers(0, 2**31 - 1))
def test_uct_exploitation_dominates_at_cp0(seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(2, 12)
    n = rng.integers(1, 50, a).astype(np.float32)
    w = rng.random(a).astype(np.float32) * n      # q in [0,1]
    s = uct.uct_scores(jnp.asarray(n), jnp.asarray(w), jnp.zeros(a),
                       jnp.asarray(n.sum()), cp=0.0)
    assert int(jnp.argmax(s)) == int(np.argmax(w / n))


@given(st.integers(0, 2**31 - 1))
def test_virtual_loss_discourages_inflight(seed):
    rng = np.random.default_rng(seed)
    a = int(rng.integers(2, 10))
    n = rng.integers(1, 20, a).astype(np.float32)
    w = (rng.random(a) * n).astype(np.float32)
    base = uct.uct_scores(jnp.asarray(n), jnp.asarray(w), jnp.zeros(a),
                          jnp.asarray(n.sum()), 1.0)
    j = int(rng.integers(0, a))
    vl = jnp.zeros(a).at[j].set(3)
    with_vl = uct.uct_scores(jnp.asarray(n), jnp.asarray(w), vl,
                             jnp.asarray(n.sum()) + 3, 1.0)
    assert float(with_vl[j]) < float(base[j])


# ---------------------------------------------------------------------------
# sharding rules properties
# ---------------------------------------------------------------------------
class _FakeMesh:
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        import numpy as _np
        self.devices = _np.empty(tuple(sizes.values()))


@given(dim=st.integers(1, 4096), model=st.sampled_from([4, 8, 16]),
       data=st.sampled_from([2, 4, 16]))
def test_resolve_spec_divisibility(dim, model, data):
    mesh = _FakeMesh({"data": data, "model": model})
    spec = resolve_spec(("mlp",), (dim,), mesh, DEFAULT_RULES)
    if spec and spec[0] is not None:
        assert dim % model == 0          # only assigned when divisible


@given(b=st.sampled_from([1, 2, 8, 32, 256]),
       s=st.sampled_from([16, 4096, 32768]))
def test_resolve_spec_never_reuses_axis(b, s):
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = resolve_spec(("batch", "kv_seq", "kv", None), (b, s, 16, 64), mesh,
                        DEFAULT_RULES)
    flat = []
    for p in spec:
        if p is None:
            continue
        flat.extend(p if isinstance(p, tuple) else (p,))
    assert len(flat) == len(set(flat))   # each mesh axis used at most once


# ---------------------------------------------------------------------------
# quantization round-trip
# ---------------------------------------------------------------------------
@given(st.integers(0, 2**31 - 1))
def test_int8_quantization_error_bound(seed):
    from repro.parallel.collectives import _dequantize_int8, _quantize_int8
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=500).astype(np.float32)) * rng.uniform(0.1, 10)
    q, scale, pad = _quantize_int8(x, block=128)
    out = _dequantize_int8(q, scale, pad, x.shape, x.dtype)
    blockmax = float(jnp.abs(x).max())
    assert float(jnp.abs(out - x).max()) <= blockmax / 127.0 + 1e-6


# ---------------------------------------------------------------------------
# data pipeline determinism
# ---------------------------------------------------------------------------
@given(st.integers(0, 1000), st.integers(0, 5))
def test_data_pipeline_deterministic(step, seed):
    from repro.configs import get_smoke_config
    from repro.data import DataConfig, synthetic_batch
    cfg = get_smoke_config("smollm-135m")
    d = DataConfig(seed=seed, batch_size=2, seq_len=32)
    b1 = synthetic_batch(cfg, d, step)
    b2 = synthetic_batch(cfg, d, step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different steps differ
    b3 = synthetic_batch(cfg, d, step + 1)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
