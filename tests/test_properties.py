"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import schedule, uct
from repro.parallel.sharding import DEFAULT_RULES, resolve_spec

settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# schedule model properties (paper's pipeline arithmetic)
# ---------------------------------------------------------------------------
@given(n=st.integers(1, 64),
       costs=st.tuples(*[st.floats(0.25, 4.0) for _ in range(4)]),
       lanes=st.integers(1, 8))
def test_pipeline_never_slower_than_sequential(n, costs, lanes):
    p = schedule.pipeline_makespan(n, costs, lanes)
    s = schedule.sequential_makespan(n, costs)
    assert p <= s + 1e-9


@given(n=st.integers(2, 64),
       costs=st.tuples(*[st.floats(0.25, 4.0) for _ in range(4)]))
def test_pipeline_lower_bound_is_bottleneck(n, costs):
    """Makespan >= n / steady-state throughput (slowest-stage bound)."""
    p = schedule.pipeline_makespan(n, costs, lanes=1)
    bound = n * max(costs)
    assert p >= bound - 1e-9


@given(n=st.integers(1, 32),
       cp=st.floats(0.5, 4.0), lanes=st.integers(1, 8))
def test_lanes_saturate_at_playout_cost(n, cp, lanes):
    costs = (1.0, 1.0, cp, 1.0)
    t1 = schedule.pipeline_makespan(n, costs, lanes)
    t2 = schedule.pipeline_makespan(n, costs, lanes + 1)
    assert t2 <= t1 + 1e-9          # more lanes never hurts


# ---------------------------------------------------------------------------
# UCT scoring properties (paper eq. 1)
# ---------------------------------------------------------------------------
@given(st.integers(0, 2**31 - 1))
def test_uct_picks_unvisited_first(seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(2, 12)
    n = rng.integers(1, 50, a).astype(np.float32)
    unv = rng.integers(0, a)
    n[unv] = 0
    w = rng.normal(size=a).astype(np.float32) * 10
    s = uct.uct_scores(jnp.asarray(n), jnp.asarray(w), jnp.zeros(a),
                       jnp.asarray(n.sum()), 1.4)
    assert int(jnp.argmax(s)) == unv


@given(st.integers(0, 2**31 - 1))
def test_uct_exploitation_dominates_at_cp0(seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(2, 12)
    n = rng.integers(1, 50, a).astype(np.float32)
    w = rng.random(a).astype(np.float32) * n      # q in [0,1]
    s = uct.uct_scores(jnp.asarray(n), jnp.asarray(w), jnp.zeros(a),
                       jnp.asarray(n.sum()), cp=0.0)
    assert int(jnp.argmax(s)) == int(np.argmax(w / n))


@given(seed=st.integers(0, 2**31 - 1),
       lanes=st.integers(2, 8),
       vl_mode=st.sampled_from(("loss", "wu")))
def test_running_assignment_disperses_unvisited_siblings(seed, lanes, vl_mode):
    """Whenever co-located lanes sit at a parent with >= lanes valid idle
    unvisited children, the running assignment picks DISTINCT children: each
    pick raises that child's effective count past the must-explore threshold
    for every later lane of the wave, so the 1e30 sentinel moves on.  The
    independent assignment stacks all of them on one child (the control)."""
    rng = np.random.default_rng(seed)
    a = int(rng.integers(lanes, 14))
    n = jnp.zeros((lanes, a))
    w = jnp.asarray(np.broadcast_to(rng.normal(size=a), (lanes, a)),
                    jnp.float32)
    z = jnp.zeros((lanes, a))
    pn = jnp.ones((lanes,))
    # a shared ragged mask with at least ``lanes`` valid columns
    keep = rng.permutation(a)[:int(rng.integers(lanes, a + 1))]
    valid = jnp.zeros((a,), bool).at[jnp.asarray(keep)].set(True)
    valid = jnp.broadcast_to(valid, (lanes, a))
    rows = jnp.zeros((lanes,), jnp.int32)          # all lanes co-located
    kw = dict(valid=valid, child_o=z, vl_mode=vl_mode)
    ind = np.asarray(uct.uct_argmax(n, w, z, pn, 0.9, **kw))
    run = np.asarray(uct.uct_argmax_running(n, w, z, pn, rows, 0.9, **kw))
    assert len(set(ind.tolist())) == 1
    assert len(set(run.tolist())) == lanes
    assert np.asarray(valid)[np.arange(lanes), run].all()


@given(seed=st.integers(0, 2**31 - 1),
       lanes=st.integers(2, 10),
       groups=st.integers(1, 4),
       vl_mode=st.sampled_from(("loss", "wu")))
def test_running_never_adds_within_level_duplicates(seed, lanes, groups,
                                                    vl_mode):
    """On any single level board, running duplicates <= independent
    duplicates: independent gives every co-located group exactly one pick
    (identical rows, identical argmax -> size-1 dups per group); running can
    only split a group across more children, never fewer."""
    rng = np.random.default_rng(seed)
    a = int(rng.integers(2, 10))
    gn = rng.integers(0, 20, (groups, a)).astype(np.float32)
    gw = (rng.normal(size=(groups, a)) * 3).astype(np.float32)
    gv = rng.integers(0, 3, (groups, a)).astype(np.float32)
    gva = rng.random((groups, a)) < 0.8
    gva[:, 0] = True
    rows = jnp.asarray(rng.integers(0, groups, lanes), jnp.int32)
    n, w = jnp.asarray(gn)[rows], jnp.asarray(gw)[rows]
    vl, valid = jnp.asarray(gv)[rows], jnp.asarray(gva)[rows]
    pn = n.sum(-1) + vl.sum(-1) + 1
    kw = dict(valid=valid, child_o=vl, vl_mode=vl_mode)
    ind = np.asarray(uct.uct_argmax(n, w, vl, pn, 1.1, **kw))
    run = np.asarray(uct.uct_argmax_running(n, w, vl, pn, rows, 1.1, **kw))
    r = np.asarray(rows)
    dups = lambda pick: lanes - len({(int(g), int(p))
                                     for g, p in zip(r, pick)})
    assert dups(run) <= dups(ind)


@given(st.integers(0, 2**31 - 1))
def test_virtual_loss_discourages_inflight(seed):
    rng = np.random.default_rng(seed)
    a = int(rng.integers(2, 10))
    n = rng.integers(1, 20, a).astype(np.float32)
    w = (rng.random(a) * n).astype(np.float32)
    base = uct.uct_scores(jnp.asarray(n), jnp.asarray(w), jnp.zeros(a),
                          jnp.asarray(n.sum()), 1.0)
    j = int(rng.integers(0, a))
    vl = jnp.zeros(a).at[j].set(3)
    with_vl = uct.uct_scores(jnp.asarray(n), jnp.asarray(w), vl,
                             jnp.asarray(n.sum()) + 3, 1.0)
    assert float(with_vl[j]) < float(base[j])


# ---------------------------------------------------------------------------
# in-flight accounting invariant (DESIGN.md §15: no vloss/unobs leaks)
# ---------------------------------------------------------------------------
_DRAIN_DOM = []


def _drain_domain():
    if not _DRAIN_DOM:
        from repro.core.domains.pgame import PGameDomain
        _DRAIN_DOM.append(PGameDomain(num_actions=3, game_depth=5,
                                      binary_reward=False, seed=7))
    return _DRAIN_DOM[0]


@settings(max_examples=24, deadline=None)
@given(method=st.sampled_from(("tree", "pipeline")),
       ws=st.sampled_from(("scan", "lockstep", "mega")),
       vl_mode=st.sampled_from(("loss", "wu")),
       level_assign=st.sampled_from(("independent", "running")),
       lanes=st.sampled_from((1, 3, 4)),
       budget=st.sampled_from((9, 24)),
       seed=st.integers(0, 2**16))
def test_inflight_planes_drain_after_completed_rounds(
        method, ws, vl_mode, level_assign, lanes, budget, seed):
    """Whatever the strategy, Select order, in-flight mode, wave width, and
    budget (including masked drain ticks and lane-rounded budgets), every
    initiated playout is eventually backed up: both the ``vloss`` and the
    ``unobs`` plane return to all-zeros once the search completes.  This is
    the no-leak contract of select/expand (+1) vs backup (-1) — a masked,
    terminal, or drained lane must never leave a residual count."""
    from repro.search import SearchConfig, SearchParams, search
    dom = _drain_domain()
    sp = SearchParams(cp=0.9, max_depth=5, kernels="ref", wave_select=ws,
                      vl_mode=vl_mode, level_assign=level_assign)
    cfg = SearchConfig(method=method, budget=budget, lanes=lanes, params=sp)
    res = jax.jit(lambda r: search(dom, cfg, r))(jax.random.key(seed))
    assert bool((res.tree.vloss == 0).all()), (method, ws, vl_mode)
    assert bool((res.tree.unobs == 0).all()), (method, ws, vl_mode)


# ---------------------------------------------------------------------------
# sharding rules properties
# ---------------------------------------------------------------------------
class _FakeMesh:
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        import numpy as _np
        self.devices = _np.empty(tuple(sizes.values()))


@given(dim=st.integers(1, 4096), model=st.sampled_from([4, 8, 16]),
       data=st.sampled_from([2, 4, 16]))
def test_resolve_spec_divisibility(dim, model, data):
    mesh = _FakeMesh({"data": data, "model": model})
    spec = resolve_spec(("mlp",), (dim,), mesh, DEFAULT_RULES)
    if spec and spec[0] is not None:
        assert dim % model == 0          # only assigned when divisible


@given(b=st.sampled_from([1, 2, 8, 32, 256]),
       s=st.sampled_from([16, 4096, 32768]))
def test_resolve_spec_never_reuses_axis(b, s):
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = resolve_spec(("batch", "kv_seq", "kv", None), (b, s, 16, 64), mesh,
                        DEFAULT_RULES)
    flat = []
    for p in spec:
        if p is None:
            continue
        flat.extend(p if isinstance(p, tuple) else (p,))
    assert len(flat) == len(set(flat))   # each mesh axis used at most once


# ---------------------------------------------------------------------------
# quantization round-trip
# ---------------------------------------------------------------------------
@given(st.integers(0, 2**31 - 1))
def test_int8_quantization_error_bound(seed):
    from repro.parallel.collectives import _dequantize_int8, _quantize_int8
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=500).astype(np.float32)) * rng.uniform(0.1, 10)
    q, scale, pad = _quantize_int8(x, block=128)
    out = _dequantize_int8(q, scale, pad, x.shape, x.dtype)
    blockmax = float(jnp.abs(x).max())
    assert float(jnp.abs(out - x).max()) <= blockmax / 127.0 + 1e-6


# ---------------------------------------------------------------------------
# KV-cache invariants (cached MCTS decode, DESIGN.md §10)
# ---------------------------------------------------------------------------
_LM = []


def _lm():
    """Tiny dense model, built once per session (hypothesis examples share it)."""
    if not _LM:
        from repro.models.base import ModelConfig, get_family
        cfg = ModelConfig(name="prop", family="dense", n_layers=1, d_model=16,
                          n_heads=2, n_kv_heads=1, d_ff=32, vocab_size=32,
                          dtype="float32", ce_chunk=8, remat=False)
        _LM.append((cfg, get_family(cfg).init(cfg, jax.random.key(0))))
    return _LM[0]


def _check_prefill_then_step_matches_full_forward(seed, plen, steps):
    """Prefill at plen then incremental steps == full forward, position by
    position — the core soundness invariant of the cached decode path."""
    from repro.models.base import get_family, seq_prefill, seq_step
    cfg, params = _lm()
    rng = np.random.default_rng(seed)
    total = plen + steps
    toks = rng.integers(0, cfg.vocab_size, total).astype(np.int32)
    full = get_family(cfg).logits_fn(cfg, params, jnp.asarray(toks)[None])[0]
    # the padded buffer tail holds garbage the causal mask must hide
    buf = np.concatenate([toks[:plen],
                          rng.integers(0, cfg.vocab_size, steps + 2)])
    logits, cache = seq_prefill(cfg, params, jnp.asarray(buf, jnp.int32),
                                jnp.int32(plen))
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full[plen - 1], np.float32),
                               atol=1e-4, rtol=1e-4)
    for t in range(plen, total):
        logits, cache = seq_step(cfg, params, cache, jnp.int32(toks[t]),
                                 jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[t], np.float32),
                                   atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), plen=st.integers(1, 6),
       steps=st.integers(0, 4))
def test_prefill_then_step_matches_full_forward(seed, plen, steps):
    _check_prefill_then_step_matches_full_forward(seed, plen, steps)


def _check_cache_reset_leaks_nothing(seed, plen):
    """A slot's reset (buffer zeroed, cache re-prefilled) must leave nothing
    of the previous occupant observable: logits are invariant to (a) what the
    padded buffer tail held before the new prompt and (b) stale K/V rows
    past the valid position — both stand in for 'request A's leftovers'."""
    from repro.models.base import seq_prefill, seq_step
    cfg, params = _lm()
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
    pad = 4
    clean = np.zeros(plen + pad, np.int32)
    clean[:plen] = prompt
    dirty = rng.integers(0, cfg.vocab_size, plen + pad).astype(np.int32)
    dirty[:plen] = prompt
    lg_c, cache_c = seq_prefill(cfg, params, jnp.asarray(clean), jnp.int32(plen))
    lg_d, cache_d = seq_prefill(cfg, params, jnp.asarray(dirty), jnp.int32(plen))
    np.testing.assert_allclose(np.asarray(lg_d), np.asarray(lg_c),
                               atol=1e-5, rtol=1e-5)
    # scribble over the cache rows a previous request would have left beyond
    # the valid prefix: the next step's valid-length mask must hide them
    noise = jnp.asarray(rng.normal(size=np.shape(cache_c["k"])), jnp.float32)
    stale = jnp.arange(cache_c["k"].shape[1])[None, :, None, None] > plen
    cache_s = {kk: jnp.where(stale, vv + noise.astype(vv.dtype), vv)
               for kk, vv in cache_c.items()}
    tok = jnp.int32(int(rng.integers(0, cfg.vocab_size)))
    lg1, _ = seq_step(cfg, params, cache_c, tok, jnp.int32(plen))
    lg2, _ = seq_step(cfg, params, cache_s, tok, jnp.int32(plen))
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(lg1),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), plen=st.integers(1, 6))
def test_cache_reset_leaks_nothing(seed, plen):
    _check_cache_reset_leaks_nothing(seed, plen)


# ---------------------------------------------------------------------------
# data pipeline determinism
# ---------------------------------------------------------------------------
@given(st.integers(0, 1000), st.integers(0, 5))
def test_data_pipeline_deterministic(step, seed):
    from repro.configs import get_smoke_config
    from repro.data import DataConfig, synthetic_batch
    cfg = get_smoke_config("smollm-135m")
    d = DataConfig(seed=seed, batch_size=2, seq_len=32)
    b1 = synthetic_batch(cfg, d, step)
    b2 = synthetic_batch(cfg, d, step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different steps differ
    b3 = synthetic_batch(cfg, d, step + 1)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


# ---------------------------------------------------------------------------
# elastic requeue/merge invariance (DESIGN.md §13)
# ---------------------------------------------------------------------------
_FT_B = 6
_ft_state = {}


def _ft_fixtures():
    """Baseline computed once: the uninterrupted search_batch oracle."""
    if not _ft_state:
        from repro.core.domains.pgame import PGameDomain
        from repro.search import (SearchConfig, SearchParams, search_batch)
        dom = PGameDomain(num_actions=3, game_depth=4, binary_reward=False,
                          seed=5)
        cfg = SearchConfig(method="root", budget=16, lanes=2,
                           params=SearchParams(cp=0.7, max_depth=4),
                           keep_tree=False)
        rng = jax.random.key(13)
        _ft_state.update(dom=dom, cfg=cfg, rng=rng,
                         base=search_batch([dom] * _FT_B, cfg, rng,
                                           mesh=False))
    return _ft_state


@settings(max_examples=12, deadline=None)
@given(hosts=st.integers(1, 4), chunk=st.integers(0, 3),
       kill=st.one_of(st.none(), st.integers(0, _FT_B - 1)),
       partition_seed=st.one_of(st.none(), st.integers(0, 10)),
       requeue_seed=st.one_of(st.none(), st.integers(0, 10)))
def test_elastic_merge_is_partition_and_failure_invariant(
        hosts, chunk, kill, partition_seed, requeue_seed):
    """For random root->host partitions, failure points, and requeue orders,
    merge(surviving ∪ requeued) is bitwise the no-failure run: same visits,
    values, and stats per root."""
    from hypothesis import assume

    from repro.search import ElasticSearchDriver, FTSearchConfig
    assume(not (hosts == 1 and kill is not None))   # no survivor would remain
    st_ = _ft_fixtures()
    drv = ElasticSearchDriver(
        [st_["dom"]] * _FT_B, st_["cfg"], st_["rng"],
        FTSearchConfig(hosts=hosts, chunk=chunk, watchdog_s=0.05,
                       kill_host_at_root=kill, partition_seed=partition_seed,
                       requeue_seed=requeue_seed))
    res = drv.run()
    base = st_["base"]
    np.testing.assert_array_equal(np.asarray(res.action_visits),
                                  np.asarray(base.action_visits))
    np.testing.assert_array_equal(np.asarray(res.action_value),
                                  np.asarray(base.action_value))
    for k in base.stats:
        np.testing.assert_array_equal(np.asarray(res.stats[k]),
                                      np.asarray(base.stats[k]))
    if kill is not None:
        assert len(drv.report.lost_hosts) == 1
        assert kill in drv.report.requeued
        assert int(drv.report.runs.max()) <= 2
    else:
        assert all(drv.report.runs == 1)


# ---------------------------------------------------------------------------
# arena allocator properties (DESIGN.md §14: typed SoA arena + free-list)
# ---------------------------------------------------------------------------
def _arena_fixture(seed, n, a, grows, releases):
    """Grow a random tree in an arena, then release a random set of leaf
    rows.  Returns (arena, released_rows) as host-side values."""
    from repro.core.arena import UNEXPANDED, alloc, init_arena, release
    rng = np.random.default_rng(seed)
    ar = init_arena({"v": jnp.int32(0)}, a, n)
    live = [0]
    for _ in range(grows):
        parent = int(rng.choice(live))
        ch = np.asarray(ar.children[parent])
        free = np.flatnonzero(ch == UNEXPANDED)
        if free.size == 0:
            continue
        slot = int(rng.choice(free))
        ar, row, ok = alloc(ar)
        if not bool(ok):
            break
        ar = ar.replace(
            children=ar.children.at[parent, slot].set(row),
            parent=ar.parent.at[row].set(parent),
            action=ar.action.at[row].set(slot),
            visits=ar.visits.at[row].set(int(rng.integers(1, 9))))
        live.append(int(row))
    ch = np.asarray(ar.children)
    leaves = [r for r in live if r != 0 and (ch[r] == UNEXPANDED).all()]
    rng.shuffle(leaves)
    drop = leaves[:releases]
    for r in drop:
        p = int(np.asarray(ar.parent[r]))
        s = int(np.asarray(ar.action[r]))
        ar = ar.replace(children=ar.children.at[p, s].set(UNEXPANDED))
        ar = release(ar, jnp.int32(r))
    return ar, drop


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(4, 24),
       grows=st.integers(0, 30), releases=st.integers(0, 6))
def test_alloc_never_aliases_a_live_row(seed, n, grows, releases):
    """Whatever the alloc/release history, the next alloc returns either a
    row that is currently dead or the full-arena drop sentinel."""
    from repro.core.arena import alloc, live_mask
    ar, _ = _arena_fixture(seed, n, 3, grows, releases)
    alive = np.asarray(live_mask(ar))
    ar2, row, ok = alloc(ar)
    if bool(ok):
        assert 0 < int(row) < n
        assert not alive[int(row)]
    else:
        assert int(row) == n            # mode="drop" sentinel
        assert int(ar2.next_free) == int(ar.next_free)
        assert int(ar2.free_top) == int(ar.free_top)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(6, 24),
       grows=st.integers(4, 30), releases=st.integers(1, 6))
def test_release_then_alloc_reuses_without_corrupting_survivors(
        seed, n, grows, releases):
    """Released rows come back LIFO; draining the free-list never touches
    any surviving row's planes."""
    from repro.core.arena import alloc, live_mask
    ar, dropped = _arena_fixture(seed, n, 3, grows, releases)
    if not dropped:
        return
    before = {f: np.asarray(getattr(ar, f)).copy()
              for f in ("visits", "value", "parent", "action", "children")}
    survivors = np.flatnonzero(np.asarray(live_mask(ar)))
    got = []
    for _ in range(len(dropped)):
        ar, row, ok = alloc(ar)
        assert bool(ok)
        got.append(int(row))
    assert got == dropped[::-1]         # LIFO pop order
    assert sorted(got) == sorted(dropped)
    for f, b in before.items():
        np.testing.assert_array_equal(np.asarray(getattr(ar, f))[survivors],
                                      b[survivors], err_msg=f)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(1, 4))
def test_iterated_reroot_keeps_occupancy_bounded(seed, steps):
    """Re-rooting recycles the abandoned siblings: after every reroot the
    arena is dense (next_free == live, free list empty) and occupancy
    never exceeds what the previous tree held."""
    from repro.core.arena import (ROOT, arena_stats, live_mask, reroot,
                                  reroot_ok)
    from repro.core.tree import check_consistency
    rng = np.random.default_rng(seed)
    ar, _ = _arena_fixture(int(rng.integers(2**31)), 24, 3, 40, 0)
    for _ in range(steps):
        ch = np.asarray(ar.children[ROOT])
        cand = np.flatnonzero(ch >= 0)
        if cand.size == 0:
            break
        act = jnp.int32(int(rng.choice(cand)))
        assert bool(reroot_ok(ar, act))
        prev_live = int(np.asarray(live_mask(ar)).sum())
        ar = reroot(ar, act)
        stt = jax.tree_util.tree_map(int, arena_stats(ar))
        assert stt["live"] <= prev_live
        assert stt["next_free"] == stt["live"]      # dense after compact
        assert stt["free_top"] == 0
        assert stt["live"] + stt["capacity_left"] == ar.max_nodes
        c = check_consistency(ar)
        assert bool(c["parents_valid"]) and bool(c["vloss_drained"])
