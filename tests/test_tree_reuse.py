"""Cross-token subtree reuse + commit-time KV splice (DESIGN.md §12/§14).

Invariants under test:

* ``warm_start_root(tree, empty_root_carry(A))`` is bit-for-bit the
  identity, so a search seeded with the identity carry equals a cold
  search exactly (the statistic-level RootCarry rung, kept as
  ``root_carry``).
* Arena ``reroot`` promotes the committed child's subtree to row 0 and
  recycles every abandoned row through the free-list; ``reroot_ok`` gates
  unexpanded children.
* The searcher-threaded arena carry is the complete cross-token state: a
  fresh searcher seeded with the carried arena/action reproduces the
  threaded searcher's next step bit-for-bit.
* Soak: across >= 50 committed tokens with ``tree_reuse=True`` the arena
  occupancy stays bounded — ``next_free`` never exceeds the fixed capacity
  and plateaus (recycling works; no leak), even though cumulative
  allocations far exceed capacity.
* ``kv_splice`` changes no decisions: spliced decode == cold cached
  decode, token for token (prefill == prefill-then-step, the PR-4
  invariant).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_default_matmul_precision", "highest")

from repro.core.arena import arena_stats, live_mask  # noqa: E402
from repro.core.domains.lm_decode import CachedLMDecodeDomain  # noqa: E402
from repro.core.tree import (ROOT, UNEXPANDED, empty_root_carry,  # noqa: E402
                             init_tree, reroot, reroot_ok, root_carry,
                             warm_start_root)
from repro.search import SearchConfig, SearchParams, search, search_batch  # noqa: E402
from repro.models.base import ModelConfig, get_family  # noqa: E402
from repro.serving import (MCTSDecodeConfig, ReusableSearcher,  # noqa: E402
                           make_batched_searcher, mcts_decode_batch)

CFG = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                  dtype="float32", ce_chunk=8, remat=False)
A = 3


@pytest.fixture(scope="module")
def params():
    return get_family(CFG).init(CFG, jax.random.key(0))


def _dcfg(**kw):
    base = dict(method="pipeline", num_actions=A, budget=8, lanes=2,
                search_depth=3, rollout_len=2, cached=True)
    base.update(kw)
    return MCTSDecodeConfig(**base)


def _domain(params, prompt, plen, **extra):
    return CachedLMDecodeDomain(
        cfg=CFG, params=params, prompt=jnp.asarray(prompt, jnp.int32),
        num_actions=A, search_depth=3, rollout_len=2,
        prompt_len=jnp.int32(plen), **extra)


def _scfg(**kw):
    return SearchConfig(method="pipeline", budget=8, lanes=2, keep_tree=True,
                        params=SearchParams(cp=1.0, max_depth=3, puct=True),
                        **kw)


def _assert_trees_equal(t1, t2):
    for (p1, l1), (p2, l2) in zip(
            jax.tree_util.tree_leaves_with_path(t1),
            jax.tree_util.tree_leaves_with_path(t2)):
        assert p1 == p2
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2),
                                      err_msg=str(p1))


# -- config validation -------------------------------------------------------

def test_kv_splice_requires_cached():
    with pytest.raises(ValueError, match="cached"):
        _dcfg(kv_splice=True, cached=False)


def test_tree_reuse_rejects_root_strategy():
    with pytest.raises(ValueError, match="root"):
        _dcfg(tree_reuse=True, method="root")


def test_stateful_flag():
    assert not _dcfg().stateful
    assert _dcfg(kv_splice=True).stateful
    assert _dcfg(tree_reuse=True).stateful


def test_tree_reuse_pins_arena_capacity():
    d = _dcfg(tree_reuse=True)
    assert d.search_config().max_nodes == d.resolved_arena_nodes == 18
    assert _dcfg().search_config().max_nodes == 0
    assert _dcfg(tree_reuse=True,
                 arena_nodes=33).search_config().max_nodes == 33


# -- warm-start identity (statistic-level rung, DESIGN.md §12) ---------------

def test_identity_carry_is_bitwise_noop(params):
    dom = _domain(params, [1, 2, 3, 0, 0], 3)
    tree = init_tree(dom, max_nodes=16)
    _assert_trees_equal(warm_start_root(tree, empty_root_carry(A)), tree)


def test_identity_warm_search_equals_cold_search(params):
    prompt = [1, 2, 3, 0, 0, 0]
    rng = jax.random.key(7)
    cold = search(_domain(params, prompt, 3), _scfg(), rng)
    warm = search(_domain(params, prompt, 3,
                          root_warm=empty_root_carry(A)), _scfg(), rng)
    np.testing.assert_array_equal(np.asarray(cold.action_visits),
                                  np.asarray(warm.action_visits))
    np.testing.assert_array_equal(np.asarray(cold.action_value),
                                  np.asarray(warm.action_value))
    assert int(cold.best_action) == int(warm.best_action)
    _assert_trees_equal(cold.tree, warm.tree)


def test_dead_arena_splice_is_bitwise_cold(params):
    """A domain carrying an arena with ``root_arena_alive=False`` searches
    exactly cold — the serving searcher's dead-slot path is drift-free."""
    prompt = [1, 2, 3, 0, 0, 0]
    rng = jax.random.key(9)
    cold = search(_domain(params, prompt, 3), _scfg(), rng)
    garbage = jax.tree_util.tree_map(
        lambda x: jnp.zeros_like(x), cold.tree)
    masked = search(
        _domain(params, prompt, 3, root_arena=garbage,
                root_arena_alive=jnp.asarray(False)),
        _scfg(), rng)
    assert int(cold.best_action) == int(masked.best_action)
    _assert_trees_equal(cold.tree, masked.tree)


# -- root_carry (the renamed statistic compaction) ---------------------------

def _hand_tree(params):
    """root -> children [1, 2, -]; node 1 -> child 3."""
    dom = _domain(params, [1, 2, 3, 0, 0], 3)
    tree = init_tree(dom, max_nodes=8)
    return tree.replace(
        children=tree.children
        .at[ROOT].set(jnp.array([1, 2, UNEXPANDED]))
        .at[1].set(jnp.array([3, UNEXPANDED, UNEXPANDED])),
        parent=tree.parent.at[jnp.array([1, 2, 3])].set(
            jnp.array([0, 0, 1])),
        action=tree.action.at[jnp.array([1, 2, 3])].set(
            jnp.array([0, 1, 0])),
        visits=tree.visits.at[jnp.array([1, 2, 3])].set(
            jnp.array([5, 2, 4])),
        value=tree.value.at[jnp.array([1, 2, 3])].set(
            jnp.array([2.5, 1.0, 2.0])),
        prior=tree.prior.at[1].set(jnp.array([0.5, 0.3, 0.2])),
        next_free=jnp.asarray(4, jnp.int32))


def test_root_carry_extracts_child_statistics(params):
    tree = _hand_tree(params)
    c = jax.tree_util.tree_map(np.asarray, root_carry(tree, jnp.int32(0)))
    assert c["visits"] == 5 and c["value"] == 2.5
    np.testing.assert_allclose(c["prior"], [0.5, 0.3, 0.2])
    np.testing.assert_array_equal(c["child_visits"], [4, 0, 0])
    np.testing.assert_allclose(c["child_value"], [2.0, 0.0, 0.0])


def test_root_carry_on_unexpanded_child_is_identity_carry(params):
    dom = _domain(params, [1, 2, 3, 0, 0], 3)
    tree = init_tree(dom, max_nodes=8)        # root has no children yet
    c = root_carry(tree, jnp.int32(1))
    iden = empty_root_carry(A)
    _assert_trees_equal(jax.tree_util.tree_map(np.asarray, c),
                        jax.tree_util.tree_map(np.asarray, iden))


# -- arena reroot (full subtree reuse, DESIGN.md §14) ------------------------

def test_arena_reroot_promotes_child_and_recycles(params):
    tree = _hand_tree(params)
    assert bool(reroot_ok(tree, jnp.int32(0)))
    r = reroot(tree, jnp.int32(0))
    # committed child (old row 1) is the new root at row 0
    assert int(r.visits[ROOT]) == 5
    assert float(r.value[ROOT]) == 2.5
    np.testing.assert_allclose(np.asarray(r.prior[ROOT]), [0.5, 0.3, 0.2])
    assert int(r.parent[ROOT]) == -1
    # its grandchild (old row 3, visits 4) came along, remapped in-range
    ch = np.asarray(r.children[ROOT])
    assert ch[1] == -1 and ch[2] == -1 and ch[0] >= 0
    assert int(r.visits[ch[0]]) == 4
    assert int(r.parent[ch[0]]) == ROOT
    # exactly 2 rows live; everything else (old root, sibling 2) recycled
    st = jax.tree_util.tree_map(int, arena_stats(r))
    assert st["live"] == 2
    assert st["next_free"] == 2
    assert st["capacity_left"] == tree.max_nodes - 2


def test_reroot_ok_gates_unexpanded_child(params):
    dom = _domain(params, [1, 2, 3, 0, 0], 3)
    tree = init_tree(dom, max_nodes=8)
    assert not bool(reroot_ok(tree, jnp.int32(1)))


def test_reroot_after_real_search_keeps_invariants(params):
    res = search(_domain(params, [1, 2, 3, 0, 0, 0], 3), _scfg(),
                 jax.random.key(3))
    tree = res.tree
    act = res.best_action
    if not bool(reroot_ok(tree, act)):
        pytest.skip("best child unexpanded at this seed")
    r = reroot(tree, act)
    alive = np.asarray(live_mask(r))
    n_live = int(alive.sum())
    assert int(r.next_free) == n_live          # dense after compaction
    assert int(r.free_top) == 0
    # parents of live non-root rows are live and in-range
    par = np.asarray(r.parent)
    for i in np.nonzero(alive)[0]:
        if i == ROOT:
            assert par[i] == -1
        else:
            assert 0 <= par[i] < r.max_nodes and alive[par[i]]


# -- searcher-threaded arena carry (acceptance parity) -----------------------

def test_searcher_carry_is_the_search_tree(params):
    """The carry after a step holds exactly the searched arenas and the
    committed actions — verified against a standalone ``search_batch`` of
    the same cold domains."""
    dcfg = _dcfg(tree_reuse=True, kv_splice=False)
    scfg = dcfg.search_config()
    assert scfg.keep_tree
    buf = np.zeros((1, 6), np.int32)
    buf[0, :3] = [1, 2, 3]
    lens = np.array([3], np.int32)

    searcher = make_batched_searcher(CFG, params, dcfg, batch=1, mesh=False)
    assert isinstance(searcher, ReusableSearcher)
    carry = searcher.init_carry(buf.shape[1])
    carry = searcher.admit(carry, 0, buf[0], 3)
    assert not bool(np.asarray(carry["alive"][0]))

    rng = jax.random.key(11)
    toks, carry = searcher.step(buf, lens, rng, carry)
    dom = _domain(params, buf[0], 3)
    res = search_batch([dom], scfg, rng)
    assert bool(np.asarray(carry["alive"][0]))
    assert int(carry["action"][0]) == int(res.best_action[0])
    _, top = dom._topk(dom.root_state())
    assert int(toks[0]) == int(top[int(res.best_action[0])])
    for key in ("visits", "children", "parent", "next_free", "free_top"):
        np.testing.assert_array_equal(
            np.asarray(getattr(carry["arena"], key)),
            np.asarray(getattr(res.tree, key)), err_msg=key)


def test_seeded_carry_reproduces_threaded_run_bitwise(params):
    """The acceptance parity, fully bitwise: a FRESH searcher whose carry is
    overwritten with the threaded searcher's arena/action/alive must
    reproduce its next step exactly — same token, same carried arena, every
    leaf bit-for-bit.  Proves the carry is the complete cross-token state:
    nothing rides outside it."""
    dcfg = _dcfg(tree_reuse=True, kv_splice=False)
    buf = np.zeros((1, 6), np.int32)
    buf[0, :3] = [1, 2, 3]
    lens = np.array([3], np.int32)
    searcher = make_batched_searcher(CFG, params, dcfg, batch=1, mesh=False)
    carry = searcher.init_carry(buf.shape[1])
    carry = searcher.admit(carry, 0, buf[0], 3)
    tok1, carry = searcher.step(buf, lens, jax.random.key(21), carry)
    buf[0, 3] = int(tok1[0])
    lens[0] += 1

    # threaded side: continue with the carry in hand
    tok2, carry2 = searcher.step(buf, lens, jax.random.key(22), carry)

    # seeded side: fresh searcher, carry overwritten with the carried arena
    fresh = make_batched_searcher(CFG, params, dcfg, batch=1, mesh=False)
    seeded = fresh.init_carry(buf.shape[1])
    seeded = fresh.admit(seeded, 0, buf[0], int(lens[0]))
    seeded = dict(seeded)
    for k in ("arena", "action", "alive"):
        seeded[k] = jax.tree_util.tree_map(jnp.asarray, carry[k])
    tok2b, carry2b = fresh.step(buf, lens, jax.random.key(22), seeded)

    assert int(tok2[0]) == int(tok2b[0])
    _assert_trees_equal(
        jax.tree_util.tree_map(np.asarray, carry2),
        jax.tree_util.tree_map(np.asarray, carry2b))


def test_reused_decode_differs_then_identity_at_zero(params):
    """tree_reuse deliberately changes exploration after the first token
    (carried subtree), but the FIRST token of every request — searched from
    a dead carry — matches the cold path exactly."""
    prompts = np.array([[1, 2, 3], [4, 5, 6]], np.int32)
    cold = mcts_decode_batch(CFG, params, prompts, 3, _dcfg(), seed=0)
    warm = mcts_decode_batch(CFG, params, prompts, 3,
                             _dcfg(tree_reuse=True), seed=0)
    for c, w in zip(cold, warm):
        assert c[0] == w[0]


# -- soak: bounded arena occupancy across a request lifetime -----------------

def test_soak_arena_occupancy_bounded_50_tokens(params):
    """>= 50 committed tokens through one reused slot.  Cumulative
    allocations (~budget per token, 400+) dwarf the fixed capacity (18), so
    staying under it proves rows really recycle; ``next_free`` must also
    plateau (no slow leak), and the final arena must still be consistent."""
    n_tok = 50
    dcfg = _dcfg(tree_reuse=True, search_depth=3, rollout_len=1)
    cap = dcfg.resolved_arena_nodes
    buf = np.zeros((1, 3 + n_tok), np.int32)
    buf[0, :3] = [1, 2, 3]
    lens = np.array([3], np.int32)
    searcher = make_batched_searcher(CFG, params, dcfg, batch=1, mesh=False)
    carry = searcher.init_carry(buf.shape[1])
    carry = searcher.admit(carry, 0, buf[0], 3)
    rng = jax.random.key(0)
    nf_trace, live_trace = [], []
    for _ in range(n_tok):
        rng, sub = jax.random.split(rng)
        toks, carry = searcher.step(buf, lens, sub, carry)
        ar = jax.tree_util.tree_map(lambda x: x[0], carry["arena"])
        st = jax.tree_util.tree_map(int, arena_stats(ar))
        assert st["next_free"] <= cap, (st, len(nf_trace))
        assert st["free_top"] >= 0
        assert st["live"] <= cap
        nf_trace.append(st["next_free"])
        live_trace.append(st["live"])
        buf[0, lens[0]] = int(toks[0])
        lens[0] += 1
    assert len(nf_trace) == n_tok
    # plateau: the high-water mark of the 2nd half never exceeds the 1st's
    assert max(nf_trace[n_tok // 2:]) <= max(nf_trace[:n_tok // 2]), nf_trace
    # the slot stayed warm and kept a real subtree alive throughout
    assert min(live_trace[1:]) >= 1


# -- kv splice ---------------------------------------------------------------

def test_kv_splice_token_parity_with_cold(params):
    """Spliced decode must equal cold cached decode token-for-token: the
    carry row after seq_step(commit) equals what prefill(prefix+tok) builds
    (the PR-4 prefill/step parity invariant), so decisions cannot drift."""
    prompts = [np.array([1, 2, 3, 4], np.int32), np.array([9, 8], np.int32)]
    cold = mcts_decode_batch(CFG, params, prompts, 4, _dcfg(), seed=3)
    spliced = mcts_decode_batch(CFG, params, prompts, 4,
                                _dcfg(kv_splice=True), seed=3)
    assert spliced == cold


def test_splice_admit_prefills_one_row_only(params):
    dcfg = _dcfg(kv_splice=True)
    searcher = make_batched_searcher(CFG, params, dcfg, batch=2, mesh=False)
    carry = searcher.init_carry(8)
    row = np.zeros(8, np.int32)
    row[:3] = [1, 2, 3]
    carry2 = searcher.admit(carry, 1, row, 3)
    # slot 0 rows untouched, slot 1 rows rewritten
    for leaf0, leaf2 in zip(jax.tree_util.tree_leaves(carry["cache"]),
                            jax.tree_util.tree_leaves(carry2["cache"])):
        np.testing.assert_array_equal(np.asarray(leaf0[0]),
                                      np.asarray(leaf2[0]))
    assert not np.array_equal(np.asarray(carry["logits"][1]),
                              np.asarray(carry2["logits"][1]))


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >1 device (CI multi-device job)")
def test_kv_splice_parity_under_mesh(params):
    """Splice parity survives mesh sharding: the stateful searcher pads and
    shards its carry along the slot axis exactly like the stateless
    searcher pads buf/lens, so decisions still match token-for-token."""
    prompts = np.array([[1, 2, 3], [4, 5, 6], [7, 8, 9]], np.int32)
    cold = mcts_decode_batch(CFG, params, prompts, 3, _dcfg(), seed=5,
                             mesh=None)
    spliced = mcts_decode_batch(CFG, params, prompts, 3,
                                _dcfg(kv_splice=True), seed=5, mesh=None)
    assert spliced == cold
    # both knobs: still drains with the carry sharded over the mesh
    warm = mcts_decode_batch(CFG, params, prompts, 3,
                             _dcfg(kv_splice=True, tree_reuse=True), seed=5,
                             mesh=None)
    assert all(len(w) == 3 for w in warm)


def test_domain_contract_with_reuse_fields(params):
    from repro.search import check_domain
    dom = _domain(params, [1, 2, 3, 0, 0], 3, root_warm=empty_root_carry(A))
    assert check_domain(dom)
