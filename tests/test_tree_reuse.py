"""Cross-token subtree reuse + commit-time KV splice (DESIGN.md §12).

Invariants under test:

* ``warm_start_root(tree, empty_root_carry(A))`` is bit-for-bit the
  identity, so a search seeded with the identity carry equals a cold
  search exactly — the admission reset in serving is free of drift.
* ``reroot`` compacts exactly the chosen child's N/W, prior row and
  grandchild stats, with the identity fallback on unexpanded children.
* The searcher-threaded carry equals the explicit path — a search whose
  domain is seeded with the carried visit counts — bit-for-bit on both
  the emitted tokens and the carried statistics (the acceptance parity).
* ``kv_splice`` changes no decisions: spliced decode == cold cached
  decode, token for token (prefill == prefill-then-step, the PR-4
  invariant).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_default_matmul_precision", "highest")

from repro.core.domains.lm_decode import CachedLMDecodeDomain  # noqa: E402
from repro.core.tree import (ROOT, UNEXPANDED, empty_root_carry,  # noqa: E402
                             init_tree, reroot, warm_start_root)
from repro.search import SearchConfig, SearchParams, search, search_batch  # noqa: E402
from repro.models.base import ModelConfig, get_family  # noqa: E402
from repro.serving import (MCTSDecodeConfig, ReusableSearcher,  # noqa: E402
                           make_batched_searcher, mcts_decode_batch)

CFG = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                  dtype="float32", ce_chunk=8, remat=False)
A = 3


@pytest.fixture(scope="module")
def params():
    return get_family(CFG).init(CFG, jax.random.key(0))


def _dcfg(**kw):
    base = dict(method="pipeline", num_actions=A, budget=8, lanes=2,
                search_depth=3, rollout_len=2, cached=True)
    base.update(kw)
    return MCTSDecodeConfig(**base)


def _domain(params, prompt, plen, **extra):
    return CachedLMDecodeDomain(
        cfg=CFG, params=params, prompt=jnp.asarray(prompt, jnp.int32),
        num_actions=A, search_depth=3, rollout_len=2,
        prompt_len=jnp.int32(plen), **extra)


def _scfg():
    return SearchConfig(method="pipeline", budget=8, lanes=2, keep_tree=True,
                        params=SearchParams(cp=1.0, max_depth=3, puct=True))


def _assert_trees_equal(t1, t2):
    for (p1, l1), (p2, l2) in zip(
            jax.tree_util.tree_leaves_with_path(t1),
            jax.tree_util.tree_leaves_with_path(t2)):
        assert p1 == p2
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2),
                                      err_msg=str(p1))


# -- config validation -------------------------------------------------------

def test_kv_splice_requires_cached():
    with pytest.raises(ValueError, match="cached"):
        _dcfg(kv_splice=True, cached=False)


def test_tree_reuse_rejects_root_strategy():
    with pytest.raises(ValueError, match="root"):
        _dcfg(tree_reuse=True, method="root")


def test_stateful_flag():
    assert not _dcfg().stateful
    assert _dcfg(kv_splice=True).stateful
    assert _dcfg(tree_reuse=True).stateful


# -- warm-start identity -----------------------------------------------------

def test_identity_carry_is_bitwise_noop(params):
    dom = _domain(params, [1, 2, 3, 0, 0], 3)
    tree = init_tree(dom, max_nodes=16)
    _assert_trees_equal(warm_start_root(tree, empty_root_carry(A)), tree)


def test_identity_warm_search_equals_cold_search(params):
    prompt = [1, 2, 3, 0, 0, 0]
    rng = jax.random.key(7)
    cold = search(_domain(params, prompt, 3), _scfg(), rng)
    warm = search(_domain(params, prompt, 3,
                          root_warm=empty_root_carry(A)), _scfg(), rng)
    np.testing.assert_array_equal(np.asarray(cold.action_visits),
                                  np.asarray(warm.action_visits))
    np.testing.assert_array_equal(np.asarray(cold.action_value),
                                  np.asarray(warm.action_value))
    assert int(cold.best_action) == int(warm.best_action)
    _assert_trees_equal(cold.tree, warm.tree)


# -- reroot ------------------------------------------------------------------

def test_reroot_extracts_child_statistics(params):
    dom = _domain(params, [1, 2, 3, 0, 0], 3)
    tree = init_tree(dom, max_nodes=8)
    # hand-build: root has children [1, 2, -1]; node 1 has child 3
    tree["children"] = tree["children"].at[ROOT].set(
        jnp.array([1, 2, UNEXPANDED]))
    tree["children"] = tree["children"].at[1].set(
        jnp.array([3, UNEXPANDED, UNEXPANDED]))
    tree["visits"] = tree["visits"].at[jnp.array([1, 2, 3])].set(
        jnp.array([5, 2, 4]))
    tree["value"] = tree["value"].at[jnp.array([1, 2, 3])].set(
        jnp.array([2.5, 1.0, 2.0]))
    tree["prior"] = tree["prior"].at[1].set(jnp.array([0.5, 0.3, 0.2]))
    c = jax.tree_util.tree_map(np.asarray, reroot(tree, jnp.int32(0)))
    assert c["visits"] == 5 and c["value"] == 2.5
    np.testing.assert_allclose(c["prior"], [0.5, 0.3, 0.2])
    np.testing.assert_array_equal(c["child_visits"], [4, 0, 0])
    np.testing.assert_allclose(c["child_value"], [2.0, 0.0, 0.0])


def test_reroot_on_unexpanded_child_is_identity_carry(params):
    dom = _domain(params, [1, 2, 3, 0, 0], 3)
    tree = init_tree(dom, max_nodes=8)        # root has no children yet
    c = reroot(tree, jnp.int32(1))
    iden = empty_root_carry(A)
    _assert_trees_equal(jax.tree_util.tree_map(np.asarray, c),
                        jax.tree_util.tree_map(np.asarray, iden))


def test_warm_start_root_blends_prior_with_grandchild_visits(params):
    dom = _domain(params, [1, 2, 3, 0, 0], 3)
    tree = init_tree(dom, max_nodes=8)
    carry = {"visits": jnp.int32(6), "value": jnp.float32(3.0),
             "prior": jnp.array([0.5, 0.25, 0.25]),
             "child_visits": jnp.array([4, 1, 0], jnp.int32),
             "child_value": jnp.array([2.0, 0.5, 0.0])}
    t = warm_start_root(tree, carry)
    assert int(t["visits"][ROOT]) == 6
    assert float(t["value"][ROOT]) == 3.0
    np.testing.assert_allclose(
        np.asarray(t["prior"][ROOT]),
        np.array([4.5, 1.25, 0.25]) / 6.0, rtol=1e-6)


# -- searcher-threaded carry == explicitly seeded search (acceptance) --------

def test_searcher_carry_matches_explicitly_seeded_search(params):
    """Thread the carry through ReusableSearcher for two tokens; replay the
    same two searches with the carried statistics seeded explicitly into a
    fresh domain.  Tokens and carried visit counts must match bit-for-bit;
    float leaves (value sums, priors) to tight tolerance — the searcher
    fuses its search into one XLA program with the token/reroot ops while
    the replay runs ``search_batch`` standalone, and fusion may differ in
    the last ulp.  (The fully-bitwise seeded-carry check is the test
    below, which routes both runs through the same compiled step.)
    """
    dcfg = _dcfg(tree_reuse=True, kv_splice=False)
    scfg = dcfg.search_config()
    assert scfg.keep_tree
    prompt = np.array([1, 2, 3], np.int32)
    buf = np.zeros((1, 6), np.int32)
    buf[0, :3] = prompt
    lens = np.array([3], np.int32)

    searcher = make_batched_searcher(CFG, params, dcfg, batch=1, mesh=False)
    assert isinstance(searcher, ReusableSearcher)
    carry = searcher.init_carry(buf.shape[1])
    carry = searcher.admit(carry, 0, buf[0], 3)

    rng1, rng2 = jax.random.key(11), jax.random.key(12)
    explicit = empty_root_carry(A)            # what admit seeds
    for tok_rng in (rng1, rng2):
        toks, carry = searcher.step(buf, lens, tok_rng, carry)
        # explicit path: same batched search, carry seeded via the domain
        dom = CachedLMDecodeDomain(
            cfg=CFG, params=params, prompt=jnp.asarray(buf[0]),
            num_actions=A, search_depth=dcfg.search_depth,
            rollout_len=dcfg.rollout_len, prompt_len=jnp.int32(lens[0]),
            root_warm=explicit)
        res = search_batch([dom], scfg, tok_rng)
        tree0 = jax.tree_util.tree_map(lambda x: x[0], res.tree)
        explicit = reroot(tree0, res.best_action[0])
        _, top = dom._topk(dom.root_state())
        assert int(toks[0]) == int(top[int(res.best_action[0])])
        got = jax.tree_util.tree_map(lambda x: np.asarray(x[0]),
                                     carry["warm"])
        want = jax.tree_util.tree_map(np.asarray, explicit)
        for key in ("visits", "child_visits"):              # bit-for-bit
            np.testing.assert_array_equal(got[key], want[key], err_msg=key)
        for key in ("value", "prior", "child_value"):
            np.testing.assert_allclose(got[key], want[key],
                                       rtol=1e-5, atol=1e-6, err_msg=key)
        buf[0, lens[0]] = int(toks[0])
        lens[0] += 1


def test_seeded_carry_reproduces_threaded_run_bitwise(params):
    """The acceptance parity, fully bitwise: a FRESH searcher whose
    identity carry is overwritten with the carried statistics (the seeded
    cold search) must reproduce the threaded searcher's next step exactly —
    same token, same carried stats, every leaf bit-for-bit.  Proves the
    carry is the complete cross-token state: nothing rides outside it."""
    dcfg = _dcfg(tree_reuse=True, kv_splice=False)
    buf = np.zeros((1, 6), np.int32)
    buf[0, :3] = [1, 2, 3]
    lens = np.array([3], np.int32)
    searcher = make_batched_searcher(CFG, params, dcfg, batch=1, mesh=False)
    carry = searcher.init_carry(buf.shape[1])
    carry = searcher.admit(carry, 0, buf[0], 3)
    tok1, carry = searcher.step(buf, lens, jax.random.key(21), carry)
    buf[0, 3] = int(tok1[0])
    lens[0] += 1

    # threaded side: continue with the carry in hand
    tok2, carry2 = searcher.step(buf, lens, jax.random.key(22), carry)

    # seeded side: fresh searcher, identity carry overwritten with the
    # carried visit counts/values — i.e. a cold search explicitly seeded
    fresh = make_batched_searcher(CFG, params, dcfg, batch=1, mesh=False)
    seeded = fresh.init_carry(buf.shape[1])
    seeded = fresh.admit(seeded, 0, buf[0], int(lens[0]))
    seeded = dict(seeded)
    seeded["warm"] = jax.tree_util.tree_map(jnp.asarray, carry["warm"])
    tok2b, carry2b = fresh.step(buf, lens, jax.random.key(22), seeded)

    assert int(tok2[0]) == int(tok2b[0])
    _assert_trees_equal(
        jax.tree_util.tree_map(np.asarray, carry2),
        jax.tree_util.tree_map(np.asarray, carry2b))


def test_reused_decode_differs_then_identity_at_zero(params):
    """tree_reuse deliberately changes exploration after the first token
    (warm priors), but the FIRST token of every request — searched from the
    identity carry — matches the cold path exactly."""
    prompts = np.array([[1, 2, 3], [4, 5, 6]], np.int32)
    cold = mcts_decode_batch(CFG, params, prompts, 3, _dcfg(), seed=0)
    warm = mcts_decode_batch(CFG, params, prompts, 3,
                             _dcfg(tree_reuse=True), seed=0)
    for c, w in zip(cold, warm):
        assert c[0] == w[0]


# -- kv splice ---------------------------------------------------------------

def test_kv_splice_token_parity_with_cold(params):
    """Spliced decode must equal cold cached decode token-for-token: the
    carry row after seq_step(commit) equals what prefill(prefix+tok) builds
    (the PR-4 prefill/step parity invariant), so decisions cannot drift."""
    prompts = [np.array([1, 2, 3, 4], np.int32), np.array([9, 8], np.int32)]
    cold = mcts_decode_batch(CFG, params, prompts, 4, _dcfg(), seed=3)
    spliced = mcts_decode_batch(CFG, params, prompts, 4,
                                _dcfg(kv_splice=True), seed=3)
    assert spliced == cold


def test_splice_admit_prefills_one_row_only(params):
    dcfg = _dcfg(kv_splice=True)
    searcher = make_batched_searcher(CFG, params, dcfg, batch=2, mesh=False)
    carry = searcher.init_carry(8)
    row = np.zeros(8, np.int32)
    row[:3] = [1, 2, 3]
    carry2 = searcher.admit(carry, 1, row, 3)
    # slot 0 rows untouched, slot 1 rows rewritten
    for leaf0, leaf2 in zip(jax.tree_util.tree_leaves(carry["cache"]),
                            jax.tree_util.tree_leaves(carry2["cache"])):
        np.testing.assert_array_equal(np.asarray(leaf0[0]),
                                      np.asarray(leaf2[0]))
    assert not np.array_equal(np.asarray(carry["logits"][1]),
                              np.asarray(carry2["logits"][1]))


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >1 device (CI multi-device job)")
def test_kv_splice_parity_under_mesh(params):
    """Splice parity survives mesh sharding: the stateful searcher pads and
    shards its carry along the slot axis exactly like the stateless
    searcher pads buf/lens, so decisions still match token-for-token."""
    prompts = np.array([[1, 2, 3], [4, 5, 6], [7, 8, 9]], np.int32)
    cold = mcts_decode_batch(CFG, params, prompts, 3, _dcfg(), seed=5,
                             mesh=None)
    spliced = mcts_decode_batch(CFG, params, prompts, 3,
                                _dcfg(kv_splice=True), seed=5, mesh=None)
    assert spliced == cold
    # both knobs: still drains with the carry sharded over the mesh
    warm = mcts_decode_batch(CFG, params, prompts, 3,
                             _dcfg(kv_splice=True, tree_reuse=True), seed=5,
                             mesh=None)
    assert all(len(w) == 3 for w in warm)


def test_domain_contract_with_reuse_fields(params):
    from repro.search import check_domain
    dom = _domain(params, [1, 2, 3, 0, 0], 3, root_warm=empty_root_carry(A))
    assert check_domain(dom)
