"""WU-UCT in-flight statistics (``vl_mode="wu"``, DESIGN.md §15).

The acceptance bar of the WU-UCT ISSUE, proven four ways:

* the Q-corruption fix itself — wu-mode Q is BIT-IDENTICAL whether 0 or 8
  playouts are in flight through a child, while loss-mode Q moves (the bug
  the mode exists to remove);
* wu bit-for-bit parity across the scan / lockstep / mega wave_select
  paths at ``lanes == 1`` for all five strategies (`kernels="ref"`; the
  Pallas megakernel twin is covered below in interpret mode — strategy-level
  Pallas launches need a TPU);
* wu ref fused round/tick vs the Pallas megakernel (interpret=True) —
  bit-for-bit over every arena plane at lanes 1/4/8, including the new
  ``unobs`` plane riding the input/output-aliased in-flight slot;
* single-flight strategies (sequential / root / leaf — never more than one
  playout in flight at selection time) are bitwise UNCHANGED by the mode.

Post-run invariants (unobs drained to zero) ride along on every case.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stages as S
from repro.core import uct
from repro.core.domains.pgame import PGameDomain
from repro.core.tree import check_consistency, init_tree
from repro.kernels.search_wave import ops, ref
from repro.search import SearchConfig, SearchParams, search

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=3)
SP_WU = S.SearchParams(cp=0.7, max_depth=6, kernels="ref", vl_mode="wu")
ALL_METHODS = ("sequential", "root", "leaf", "tree", "pipeline")
PLANES = ("visits", "value", "vloss", "unobs", "children", "parent",
          "action", "prior", "terminal", "next_free", "free_top")


def _assert_same_arena(ta, tb, msg=""):
    for f in PLANES:
        np.testing.assert_array_equal(np.asarray(getattr(ta, f)),
                                      np.asarray(getattr(tb, f)),
                                      err_msg=f"{msg}{f}")


def _run(method, ws, lanes, seed=0, budget=64, vl_mode="wu"):
    sp = SearchParams(cp=0.7, max_depth=6, wave_select=ws, kernels="ref",
                      vl_mode=vl_mode)
    cfg = SearchConfig(method=method, budget=budget, lanes=lanes, params=sp)
    return jax.jit(lambda r: search(DOM, cfg, r))(jax.random.key(seed))


def _assert_same_result(a, b):
    np.testing.assert_array_equal(np.asarray(a.action_visits),
                                  np.asarray(b.action_visits))
    np.testing.assert_array_equal(np.asarray(a.action_value),
                                  np.asarray(b.action_value))
    if a.tree is not None and b.tree is not None:   # root keeps no tree
        for k in ("visits", "value", "children", "vloss", "unobs"):
            np.testing.assert_array_equal(np.asarray(getattr(a.tree, k)),
                                          np.asarray(getattr(b.tree, k)),
                                          err_msg=k)
    for k in a.stats:
        assert int(a.stats[k]) == int(b.stats[k]), k


# ---------------------------------------------------------------------------
# the fix itself: in-flight playouts cannot move wu-mode Q
# ---------------------------------------------------------------------------
def test_wu_q_bit_identical_under_inflight_playouts():
    """Q with 0 in-flight playouts == Q with 8 in-flight playouts, bitwise.
    Measured through the exploration-free slice of uct_scores (cp=0) so the
    score IS Q; the loss-mode control shows the corruption being removed."""
    n = jnp.asarray([[5.0, 9.0, 2.0, 1.0]])
    w = jnp.asarray([[2.5, -3.0, 1.0, 0.5]])
    zero = jnp.zeros_like(n)
    eight = jnp.full_like(n, 8.0)
    pn = n.sum(-1)
    q_idle = uct.uct_scores(n, w, zero, pn, 0.0, child_o=zero, vl_mode="wu")
    q_busy = uct.uct_scores(n, w, eight, pn + 32, 0.0, child_o=eight,
                            vl_mode="wu")
    np.testing.assert_array_equal(np.asarray(q_idle), np.asarray(q_busy))
    np.testing.assert_array_equal(np.asarray(q_idle), np.asarray(w / n))
    # control: classic virtual loss drags Q down while playouts are in flight
    l_idle = uct.uct_scores(n, w, zero, pn, 0.0, vl_mode="loss")
    l_busy = uct.uct_scores(n, w, eight, pn + 32, 0.0, vl_mode="loss")
    assert bool((np.asarray(l_busy) < np.asarray(l_idle)).all())


def test_wu_inflight_widens_exploration_only():
    """O feeds the explore term: with cp > 0 an in-flight child's score
    drops below its idle score by exactly the explore-term shrinkage."""
    n = jnp.asarray([[4.0, 4.0]])
    w = jnp.asarray([[1.0, 1.0]])
    o = jnp.asarray([[0.0, 6.0]])
    pn = n.sum(-1) + o.sum(-1)
    s = uct.uct_scores(n, w, jnp.zeros_like(n), pn, 1.0, child_o=o,
                       vl_mode="wu")
    s = np.asarray(s)[0]
    q = 1.0 / 4.0
    np.testing.assert_allclose(s[0], q + np.sqrt(np.log(14.0) / 4.0),
                               rtol=1e-6)
    np.testing.assert_allclose(s[1], q + np.sqrt(np.log(14.0) / 10.0),
                               rtol=1e-6)
    assert s[1] < s[0]


# ---------------------------------------------------------------------------
# acceptance bar: wu bit-for-bit across scan / lockstep / mega at lanes=1
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", ALL_METHODS)
@pytest.mark.parametrize("ws", ("lockstep", "mega"))
def test_wu_wave_select_parity_at_lanes1(method, ws):
    a = _run(method, "scan", 1)
    b = _run(method, ws, 1)
    _assert_same_result(a, b)


@pytest.mark.parametrize("method", ("sequential", "root", "leaf"))
def test_wu_equals_loss_for_single_flight_strategies(method):
    """Never more than one playout in flight at selection time, so the two
    modes select identical children — bitwise-equal runs."""
    a = _run(method, "scan", 4, vl_mode="loss")
    b = _run(method, "scan", 4, vl_mode="wu")
    _assert_same_result(a, b)


@pytest.mark.parametrize("method", ("tree", "pipeline"))
@pytest.mark.parametrize("ws", ("scan", "lockstep", "mega"))
@pytest.mark.parametrize("lanes", (1, 4))
def test_wu_unobs_drains_and_invariants(method, ws, lanes):
    res = _run(method, ws, lanes, budget=96)
    c = check_consistency(res.tree)
    assert bool(c["unobs_drained"]), c
    assert bool(c["vloss_drained"]), c
    assert bool(c["visit_flow"]), c
    assert int(res.tree.visits[0]) == 96


# ---------------------------------------------------------------------------
# wu ref fused wave vs the Pallas megakernel (interpret mode), bit-for-bit
# ---------------------------------------------------------------------------
def _scan_rounds(fn, lanes, rounds, seed, nodes=64):
    tree0 = init_tree(DOM, nodes)
    def body(tree, rng):
        tree, sel = fn(tree, lanes, rng)
        return tree, sel["dup"].sum()
    rngs = jax.random.split(jax.random.key(seed), rounds)
    return jax.lax.scan(body, tree0, rngs)


@pytest.mark.parametrize("lanes", (1, 4, 8))
def test_wu_pallas_interpret_round_bitwise_equals_ref(lanes):
    ta, da = _scan_rounds(
        lambda t, l, r: ref.tree_round(t, DOM, SP_WU, l, jnp.asarray(True), r),
        lanes, 6, 0)
    tb, db = _scan_rounds(
        lambda t, l, r: ops.tree_round(t, DOM, SP_WU, l, jnp.asarray(True), r,
                                       impl="pallas", interpret=True),
        lanes, 6, 0)
    _assert_same_arena(ta, tb)
    np.testing.assert_array_equal(np.asarray(da), np.asarray(db))
    assert bool((np.asarray(ta.unobs) == 0).all())


def _scan_ticks(fn, lanes, ticks, seed, nodes=64):
    tree = init_tree(DOM, nodes)
    carry = (tree, S.empty_selection(SP_WU, lanes),
             S.empty_expansion(SP_WU, lanes, DOM),
             S.empty_playout(SP_WU, lanes, DOM.num_actions))
    def body(c, inp):
        t, rng = inp
        tree, se, ep, pb = c
        tree, se, ep, pb = fn(tree, lanes, t < ticks - 3, se, ep, pb, rng)
        return (tree, se, ep, pb), se["dup"].sum()
    rngs = jax.random.split(jax.random.key(seed), ticks)
    (tree, *_), dups = jax.lax.scan(body, carry, (jnp.arange(ticks), rngs))
    return tree, dups


@pytest.mark.parametrize("lanes", (1, 4, 8))
def test_wu_pallas_interpret_tick_bitwise_equals_ref(lanes):
    ta, da = _scan_ticks(
        lambda t, l, wv, se, ep, pb, r:
            ref.pipeline_tick(t, DOM, SP_WU, l, wv, se, ep, pb, r),
        lanes, 9, 1)
    tb, db = _scan_ticks(
        lambda t, l, wv, se, ep, pb, r:
            ops.pipeline_tick(t, DOM, SP_WU, l, wv, se, ep, pb, r,
                              impl="pallas", interpret=True),
        lanes, 9, 1)
    _assert_same_arena(ta, tb)
    np.testing.assert_array_equal(np.asarray(da), np.asarray(db))
    assert bool((np.asarray(ta.unobs) == 0).all())


# ---------------------------------------------------------------------------
# knob surface
# ---------------------------------------------------------------------------
def test_vl_mode_validation_and_default():
    assert SearchParams().vl_mode == "loss"
    assert SearchParams(vl_mode="wu").wu
    assert not SearchParams().wu
    with pytest.raises(ValueError, match="vl_mode"):
        SearchParams(vl_mode="nope")
    with pytest.raises(ValueError, match="vl_mode"):
        uct.uct_scores(jnp.ones((1, 2)), jnp.ones((1, 2)), jnp.ones((1, 2)),
                       jnp.ones((1,)), 1.0, vl_mode="nope")


def test_search_config_threads_vl_mode():
    cfg = SearchConfig(vl_mode="wu")
    assert cfg.params.vl_mode == "wu"
    # an explicit params vl_mode wins over the config-level convenience knob
    sp = SearchParams(vl_mode="wu")
    assert SearchConfig(params=sp).params.vl_mode == "wu"
