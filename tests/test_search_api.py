"""The unified ``repro.search`` API: cross-strategy parity at equal budget,
batched multi-root search, the Domain protocol, the registry, and the
removal of the deprecated ``core.run_*`` shims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.domains.pgame import PGameDomain, optimal_root_action
from repro.search import (STATS_KEYS, Domain, SearchConfig, SearchParams,
                          SearchResult, check_domain, list_strategies,
                          register_strategy, search, search_batch)

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=3)
SP = SearchParams(cp=0.7, max_depth=6)
METHODS = ("sequential", "root", "leaf", "tree", "pipeline")


def _run(method, budget=64, lanes=4, seed=0, **kw):
    cfg = SearchConfig(method=method, budget=budget, lanes=lanes, params=SP, **kw)
    return jax.jit(lambda r: search(DOM, cfg, r))(jax.random.key(seed))


# ---------------------------------------------------------------------------
# cross-strategy equal-budget parity
# ---------------------------------------------------------------------------
def test_all_methods_registered():
    assert set(METHODS) <= set(list_strategies())


@pytest.mark.parametrize("method", METHODS)
def test_strategy_runs_under_jit_with_common_schema(method):
    res = _run(method)
    assert isinstance(res, SearchResult)
    assert set(res.stats) == set(STATS_KEYS)
    assert res.action_visits.shape == (DOM.num_actions,)
    assert res.action_value.shape == (DOM.num_actions,)
    assert 0 <= int(res.best_action) < DOM.num_actions
    # equal-budget invariant: every strategy performs >= the requested budget
    assert int(res.stats["playouts_completed"]) >= 64
    assert int(res.stats["playouts_completed"]) == int(res.stats["playouts"])
    assert int(res.stats["playouts_requested"]) == int(res.stats["playouts_completed"])


@pytest.mark.parametrize("method", METHODS)
def test_visits_conservation_at_root(method):
    """Root child visits account for every completed playout (minus those
    that terminated at the root before expanding a child)."""
    res = _run(method, budget=128)
    completed = int(res.stats["playouts_completed"])
    child_sum = int(res.action_visits.sum())
    assert child_sum <= completed
    assert child_sum >= completed - 8          # only the first expansions miss
    if res.tree is not None:
        assert int(res.tree["visits"][0]) == completed
        assert bool((res.tree["vloss"] == 0).all())


@pytest.mark.parametrize("method", METHODS)
def test_duplicates_stat_means_one_thing(method):
    """``duplicates`` = "the selected leaf already had in-flight playouts"
    (strategies.py docstring) for every strategy.  Single-trajectory
    strategies (sequential/root/leaf — one playout in flight at a time) and
    tree-parallel at lanes=1 (each round drains before the next Select)
    must measure exactly 0; wave strategies with real concurrency must
    measure > 0 (the first wave's co-located lanes share the root leaf)."""
    if method in ("sequential", "root", "leaf"):
        assert int(_run(method, budget=64, lanes=4).stats["duplicates"]) == 0
        return
    if method == "tree":
        assert int(_run(method, budget=64, lanes=1).stats["duplicates"]) == 0
    assert int(_run(method, budget=128, lanes=8).stats["duplicates"]) > 0


def test_sequential_pipeline_agree_at_lanes1():
    """lanes=1 pipeline is the linear pipeline — same trajectory structure as
    sequential, so at a converged budget both recommend the optimum."""
    opt = optimal_root_action(DOM)
    seq = _run("sequential", budget=512, lanes=1)
    pipe = _run("pipeline", budget=512, lanes=1)
    assert int(seq.best_action) == int(pipe.best_action) == opt


# ---------------------------------------------------------------------------
# batched multi-root search
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", ("sequential", "pipeline"))
def test_search_batch_matches_individual_calls(method):
    cfg = SearchConfig(method=method, budget=64, lanes=4, params=SP)
    rng = jax.random.key(42)
    bres = search_batch([DOM] * 4, cfg, rng)
    keys = jax.random.split(rng, 4)
    assert bres.action_visits.shape == (4, DOM.num_actions)
    for i in range(4):
        ind = search(DOM, cfg, keys[i])
        np.testing.assert_array_equal(np.asarray(bres.action_visits[i]),
                                      np.asarray(ind.action_visits))
        np.testing.assert_allclose(np.asarray(bres.action_value[i]),
                                   np.asarray(ind.action_value), rtol=1e-5)
        assert int(bres.best_action[i]) == int(ind.best_action)
        for k in STATS_KEYS:
            assert int(bres.stats[k][i]) == int(ind.stats[k])


def test_search_batch_stacks_differing_domain_fields():
    """The stacked-varying-fields path honors the same per-element RNG/parity
    contract as the identical-domains fast path."""
    doms = [PGameDomain(num_actions=4, game_depth=6, binary_reward=True,
                        seed=3, threshold=t) for t in (0.4, 0.5, 0.6)]
    cfg = SearchConfig(method="sequential", budget=32, params=SP,
                       keep_tree=False)
    rng = jax.random.key(0)
    res = search_batch(doms, cfg, rng)
    assert res.action_visits.shape == (3, 4)
    keys = jax.random.split(rng, 3)
    for i, d in enumerate(doms):
        ind = search(d, cfg, keys[i])
        np.testing.assert_array_equal(np.asarray(res.action_visits[i]),
                                      np.asarray(ind.action_visits))
        assert int(res.best_action[i]) == int(ind.best_action)


def test_search_batch_rejects_mixed_types():
    class Other:
        pass
    with pytest.raises(TypeError):
        search_batch([DOM, Other()], SearchConfig(), jax.random.key(0))


def test_search_batch_rejects_differing_static_ints():
    doms = [PGameDomain(num_actions=4, game_depth=6),
            PGameDomain(num_actions=8, game_depth=6)]
    with pytest.raises(TypeError, match="num_actions"):
        search_batch(doms, SearchConfig(budget=8), jax.random.key(0))


def test_search_batch_accepts_equal_valued_distinct_instances():
    """Separately-constructed but equal domains are one static domain, not a
    spurious 'varying field' error."""
    doms = [PGameDomain(num_actions=4, game_depth=4, seed=1)
            for _ in range(3)]
    res = search_batch(doms, SearchConfig(budget=16, keep_tree=False),
                       jax.random.key(0))
    assert res.action_visits.shape == (3, 4)


# ---------------------------------------------------------------------------
# Domain protocol + config knobs
# ---------------------------------------------------------------------------
def test_check_domain_passes_pgame():
    assert check_domain(DOM)
    assert isinstance(DOM, Domain)


def test_check_domain_rejects_non_domain():
    class NotADomain:
        num_actions = 4
    with pytest.raises(TypeError, match="missing"):
        check_domain(NotADomain())


def test_check_domain_reports_bad_step():
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class BadStep(PGameDomain):
        def step(self, state, action):
            s = super().step(state, action)
            return {**s, "extra": jnp.float32(0.0)}   # structure change
    with pytest.raises(TypeError, match="step"):
        check_domain(BadStep(num_actions=4, game_depth=6))


def test_search_rejects_non_domain():
    with pytest.raises(TypeError, match="Domain"):
        search(object(), SearchConfig(), jax.random.key(0))


def test_unknown_method_lists_strategies():
    with pytest.raises(ValueError, match="sequential"):
        search(DOM, SearchConfig(method="nope"), jax.random.key(0))


def test_keep_tree_false_drops_tree():
    res = _run("sequential", keep_tree=False)
    assert res.tree is None


def test_register_strategy_round_trip():
    @register_strategy("_test_echo")
    def _echo(domain, cfg, rng):
        return search(domain, SearchConfig(method="sequential",
                                           budget=cfg.budget,
                                           params=cfg.params), rng)
    try:
        assert "_test_echo" in list_strategies()
        res = search(DOM, SearchConfig(method="_test_echo", budget=8,
                                       params=SP), jax.random.key(0))
        assert int(res.stats["playouts"]) == 8
    finally:
        from repro.search.api import _STRATEGIES
        _STRATEGIES.pop("_test_echo", None)


# ---------------------------------------------------------------------------
# the deprecated run_* shims are gone (grace period ended with PR 1)
# ---------------------------------------------------------------------------
def test_deprecated_shims_are_removed():
    import importlib

    import repro.core as core
    for name in ("run_sequential", "run_pipeline", "PipelineConfig"):
        assert not hasattr(core, name)
    for mod in ("sequential", "pipeline", "root_parallel", "leaf_parallel",
                "tree_parallel"):
        with pytest.raises(ModuleNotFoundError):
            importlib.import_module(f"repro.core.{mod}")
