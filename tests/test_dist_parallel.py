"""Distributed attention + explicit-EP dispatch vs single-device oracles
(8 forced host devices via subprocess)."""
import subprocess
import sys
import textwrap


def _run(code: str):
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PATH": "/usr/bin:/bin:/usr/local/bin", "HOME": "/root"})
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    return r.stdout


def test_dist_decode_attention_exact():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.parallel.compat import make_mesh
        from repro.parallel.dist_attention import dist_decode_attention
        from repro.kernels.decode_attention import ops as da
        mesh = make_mesh((4,), ("data",))
        ks = jax.random.split(jax.random.key(0), 4)
        B, S, H, Hkv, D = 2, 256, 4, 2, 32
        q = jax.random.normal(ks[0], (B, 1, H, D))
        k = jax.random.normal(ks[1], (B, S, Hkv, D))
        v = jax.random.normal(ks[2], (B, S, Hkv, D))
        vl = jnp.array([200, 97], jnp.int32)
        ref = da.decode_attention(q, k, v, vl, use_ref=True)
        got = jax.jit(lambda *a: dist_decode_attention(*a, mesh))(q, k, v, vl)
        err = float(jnp.abs(ref - got).max())
        assert err < 2e-5, err
        print("OK", err)
    """)
    assert "OK" in out


def test_ep_dispatch_matches_spmd_moe():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.parallel.compat import make_mesh
        from repro.parallel.ep_dispatch import ep_moe_ffn
        from repro.models.base import ModelConfig
        from repro.models import moe as M
        mesh = make_mesh((8,), ("model",))
        cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                          n_heads=4, d_ff=0, vocab_size=64, dtype="float32",
                          n_experts=8, moe_topk=2, d_ff_expert=16,
                          moe_capacity=100.0, moe_groups=1)
        p = M.init_moe_ffn(cfg, jax.random.key(0))
        x = jax.random.normal(jax.random.key(1), (48, 32))
        y_ref, _ = M.moe_ffn(cfg, p, x)
        y_ep = jax.jit(lambda x: ep_moe_ffn(
            x, p, mesh, topk=2, capacity_factor=100.0))(x)
        err = float(jnp.abs(y_ref - y_ep).max())
        assert err < 2e-5, err
        print("OK", err)
    """)
    assert "OK" in out


def test_ep_dispatch_differentiable():
    """EP dispatch gradients flow (it runs inside the scanned train step)."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.parallel.compat import make_mesh
        from repro.parallel.ep_dispatch import ep_moe_ffn
        from repro.models.base import ModelConfig
        from repro.models import moe as M
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                          n_heads=4, d_ff=0, vocab_size=64, dtype="float32",
                          n_experts=8, moe_topk=2, d_ff_expert=16)
        p = M.init_moe_ffn(cfg, jax.random.key(0))
        x = jax.random.normal(jax.random.key(1), (64, 32))
        f = lambda p, x: (ep_moe_ffn(x, p, mesh, topk=2) ** 2).sum()
        g = jax.jit(jax.grad(f))(p, x)
        total = sum(float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(g))
        assert total > 0 and jnp.isfinite(jnp.asarray(total))
        print("OK", total)
    """)
    assert "OK" in out
