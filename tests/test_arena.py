"""Typed SoA ``TreeArena`` + fused search-wave megakernel (DESIGN.md §14).

Three layers of contract:

* arena mechanics — alloc/release/compact/reroot row accounting, the
  free-list LIFO order, the dict-access deprecation shim, pytree round-trip;
* fused-wave parity — the ref fused round/tick (``kernels/search_wave/ref``)
  and the Pallas megakernel (interpret mode on CPU) are BIT-FOR-BIT equal
  to the unfused lockstep path on the uint32-hash PGame domain, at lanes
  1/4/8, for every integer AND float plane;
* strategy surface — all five strategies run under ``wave_select="mega"``
  and equal their lockstep selves exactly at ``lanes == 1`` (the ISSUE
  acceptance bar; sequential/root/leaf don't route wave ops, asserted as
  regression guards).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stages as S
from repro.core.arena import (ROOT, UNEXPANDED, TreeArena, alloc,
                              arena_stats, can_alloc, init_arena, live_mask,
                              release, reroot, reroot_ok)
from repro.core.domains.pgame import PGameDomain
from repro.core.tree import check_consistency, init_tree
from repro.kernels.search_wave import ops, ref
from repro.search import SearchConfig, SearchParams, search

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=3)
SP = S.SearchParams(cp=0.7, max_depth=6, kernels="ref")
PLANES = ("visits", "value", "vloss", "unobs", "children", "parent",
          "action", "prior", "terminal", "next_free", "free_top")


def _arena(n=8, a=3):
    return init_arena({"v": jnp.int32(7)}, a, n)


def _assert_same(ta, tb, fields=PLANES, msg=""):
    for f in fields:
        np.testing.assert_array_equal(np.asarray(getattr(ta, f)),
                                      np.asarray(getattr(tb, f)),
                                      err_msg=f"{msg}{f}")


# ---------------------------------------------------------------------------
# arena mechanics
# ---------------------------------------------------------------------------
def test_init_arena_root_row():
    ar = _arena()
    assert int(ar.next_free) == 1 and int(ar.free_top) == 0
    assert int(ar.parent[ROOT]) == -1
    assert bool((np.asarray(ar.children) == UNEXPANDED).all())
    assert int(np.asarray(live_mask(ar)).sum()) == 1
    assert ar.max_nodes == 8 and ar.num_actions == 3


def test_alloc_bumps_then_pops_lifo():
    ar = _arena()
    ar, r1, ok1 = alloc(ar)
    ar, r2, ok2 = alloc(ar)
    assert (int(r1), int(r2)) == (1, 2) and bool(ok1) and bool(ok2)
    ar = release(ar, jnp.array([1, 2]))
    assert int(ar.free_top) == 2
    ar, r3, _ = alloc(ar)               # LIFO: last released pops first
    assert int(r3) == 2
    ar, r4, _ = alloc(ar)
    assert int(r4) == 1
    assert int(ar.free_top) == 0 and int(ar.next_free) == 3


def test_alloc_respects_capacity():
    ar = _arena(n=3)
    ar, _, ok1 = alloc(ar)
    ar, _, ok2 = alloc(ar)
    assert bool(ok1) and bool(ok2) and not bool(can_alloc(ar))
    ar, row, ok3 = alloc(ar)
    assert not bool(ok3) and int(row) == ar.max_nodes   # drop sentinel
    assert int(ar.next_free) == 3                        # unchanged


def test_alloc_masked_is_noop():
    ar = _arena()
    ar2, row, ok = alloc(ar, jnp.asarray(False))
    assert not bool(ok) and int(row) == ar.max_nodes
    _assert_same(ar, ar2)


def test_release_resets_planes():
    ar = _arena()
    ar, r, _ = alloc(ar)
    ar = ar.replace(visits=ar.visits.at[r].set(5),
                    parent=ar.parent.at[r].set(0),
                    children=ar.children.at[r, 0].set(2))
    ar = release(ar, r)
    assert int(ar.visits[int(r)]) == 0
    assert int(ar.parent[int(r)]) == -1
    assert bool((np.asarray(ar.children[int(r)]) == UNEXPANDED).all())
    assert not bool(np.asarray(live_mask(ar))[int(r)])
    st = jax.tree_util.tree_map(int, arena_stats(ar))
    assert st["capacity_left"] == ar.max_nodes - 1


def test_dict_access_shim_warns():
    ar = _arena()
    with pytest.warns(DeprecationWarning, match="visits"):
        v = ar["visits"]
    np.testing.assert_array_equal(np.asarray(v), np.asarray(ar.visits))
    with pytest.raises(KeyError):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            ar["not_a_plane"]


def test_arena_is_a_pytree():
    ar = _arena()
    leaves, treedef = jax.tree_util.tree_flatten(ar)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, TreeArena)
    _assert_same(ar, back)
    # jit/vmap round-trip (the serving carry relies on both)
    out = jax.jit(lambda t: t.replace(visits=t.visits + 1))(ar)
    assert int(out.visits[ROOT]) == 1


def test_reroot_recycles_into_free_list():
    """Grow root->c0->g0 plus a sibling c1; reroot on action 0 keeps {c0,g0}
    and releases {root, c1} back to capacity."""
    ar = _arena(n=8, a=2)
    def attach(ar, parent, slot):
        ar, row, ok = alloc(ar)
        return ar.replace(
            children=ar.children.at[parent, slot].set(row),
            parent=ar.parent.at[row].set(parent),
            action=ar.action.at[row].set(slot)), row
    ar, c0 = attach(ar, 0, 0)
    ar, c1 = attach(ar, 0, 1)
    ar, g0 = attach(ar, int(c0), 1)
    ar = ar.replace(visits=ar.visits.at[jnp.array([0, 1, 2, 3])].set(
        jnp.array([9, 5, 3, 2])))
    assert bool(reroot_ok(ar, jnp.int32(0)))
    assert not bool(reroot_ok(ar, jnp.int32(1)) & (ar.children[0, 1] < 0))
    r = reroot(ar, jnp.int32(0))
    st = jax.tree_util.tree_map(int, arena_stats(r))
    assert st["live"] == 2 and st["next_free"] == 2
    assert st["capacity_left"] == 6
    assert int(r.visits[ROOT]) == 5                    # c0 promoted
    assert int(r.visits[int(np.asarray(r.children[ROOT, 1]))]) == 2   # g0
    c = check_consistency(r)
    assert bool(c["parents_valid"]) and bool(c["vloss_drained"])


# ---------------------------------------------------------------------------
# fused-wave parity: ref and Pallas(interpret) vs the unfused lockstep path
# ---------------------------------------------------------------------------
def _scan_rounds(fn, lanes, rounds, seed, nodes=64):
    tree0 = init_tree(DOM, nodes)
    def body(tree, rng):
        tree, sel = fn(tree, lanes, rng)
        return tree, sel["dup"].sum()
    rngs = jax.random.split(jax.random.key(seed), rounds)
    return jax.lax.scan(body, tree0, rngs)


def _unfused_round(tree, lanes, rng):
    sp = S.SearchParams(cp=SP.cp, max_depth=SP.max_depth, kernels="ref",
                        wave_select="lockstep")
    tree, sel = S.select_wave(tree, sp, lanes, jnp.asarray(True))
    tree, exps = S.expand_wave(tree, DOM, sp, sel)
    po = S.playout_wave(DOM, sp, exps, rng)
    return S.backup_wave(tree, po), sel


@pytest.mark.parametrize("lanes", (1, 4, 8))
def test_ref_fused_round_bitwise_equals_unfused(lanes):
    ta, da = _scan_rounds(_unfused_round, lanes, 6, 0)
    tb, db = _scan_rounds(
        lambda t, l, r: ref.tree_round(t, DOM, SP, l, jnp.asarray(True), r),
        lanes, 6, 0)
    _assert_same(ta, tb)
    np.testing.assert_array_equal(np.asarray(da), np.asarray(db))


@pytest.mark.parametrize("lanes", (1, 4, 8))
def test_pallas_interpret_round_bitwise_equals_ref(lanes):
    ta, da = _scan_rounds(
        lambda t, l, r: ref.tree_round(t, DOM, SP, l, jnp.asarray(True), r),
        lanes, 6, 0)
    tb, db = _scan_rounds(
        lambda t, l, r: ops.tree_round(t, DOM, SP, l, jnp.asarray(True), r,
                                       impl="pallas", interpret=True),
        lanes, 6, 0)
    _assert_same(ta, tb)
    np.testing.assert_array_equal(np.asarray(da), np.asarray(db))


def _scan_ticks(fn, lanes, ticks, seed, nodes=64):
    tree = init_tree(DOM, nodes)
    carry = (tree, S.empty_selection(SP, lanes),
             S.empty_expansion(SP, lanes, DOM),
             S.empty_playout(SP, lanes, DOM.num_actions))
    def body(c, inp):
        t, rng = inp
        tree, se, ep, pb = c
        tree, se, ep, pb = fn(tree, lanes, t < ticks - 3, se, ep, pb, rng)
        return (tree, se, ep, pb), se["dup"].sum()
    rngs = jax.random.split(jax.random.key(seed), ticks)
    (tree, *_), dups = jax.lax.scan(body, carry, (jnp.arange(ticks), rngs))
    return tree, dups


def _unfused_tick(tree, lanes, wave_valid, se, ep, pb, rng):
    sp = S.SearchParams(cp=SP.cp, max_depth=SP.max_depth, kernels="ref",
                        wave_select="lockstep")
    tree = S.backup_wave(tree, pb)
    new_pb = S.playout_wave(DOM, sp, ep, rng)
    tree, new_ep = S.expand_wave(tree, DOM, sp, se)
    tree, new_se = S.select_wave(tree, sp, lanes, wave_valid)
    return tree, new_se, new_ep, new_pb


@pytest.mark.parametrize("lanes", (1, 4, 8))
def test_ref_fused_tick_bitwise_equals_unfused(lanes):
    ta, da = _scan_ticks(_unfused_tick, lanes, 9, 1)
    tb, db = _scan_ticks(
        lambda t, l, wv, se, ep, pb, r:
            ref.pipeline_tick(t, DOM, SP, l, wv, se, ep, pb, r),
        lanes, 9, 1)
    _assert_same(ta, tb)
    np.testing.assert_array_equal(np.asarray(da), np.asarray(db))


@pytest.mark.parametrize("lanes", (1, 4, 8))
def test_pallas_interpret_tick_bitwise_equals_ref(lanes):
    ta, da = _scan_ticks(
        lambda t, l, wv, se, ep, pb, r:
            ref.pipeline_tick(t, DOM, SP, l, wv, se, ep, pb, r),
        lanes, 9, 1)
    tb, db = _scan_ticks(
        lambda t, l, wv, se, ep, pb, r:
            ops.pipeline_tick(t, DOM, SP, l, wv, se, ep, pb, r,
                              impl="pallas", interpret=True),
        lanes, 9, 1)
    _assert_same(ta, tb)
    np.testing.assert_array_equal(np.asarray(da), np.asarray(db))


# ---------------------------------------------------------------------------
# strategy surface: all five run under "mega"; lanes==1 equals lockstep
# ---------------------------------------------------------------------------
ALL_METHODS = ("sequential", "root", "leaf", "tree", "pipeline")


def _run(method, ws, lanes, seed=0, budget=64):
    sp = SearchParams(cp=0.7, max_depth=6, wave_select=ws, kernels="ref")
    cfg = SearchConfig(method=method, budget=budget, lanes=lanes, params=sp)
    return jax.jit(lambda r: search(DOM, cfg, r))(jax.random.key(seed))


@pytest.mark.parametrize("method", ALL_METHODS)
def test_mega_equals_lockstep_at_lanes1(method):
    """The ISSUE acceptance bar: every strategy under the fused wave is
    bit-for-bit its lockstep self at lanes == 1.  (sequential/root/leaf
    never route wave ops — for them this is a does-not-perturb guard.)"""
    a = _run(method, "lockstep", 1)
    b = _run(method, "mega", 1)
    np.testing.assert_array_equal(np.asarray(a.action_visits),
                                  np.asarray(b.action_visits))
    np.testing.assert_array_equal(np.asarray(a.action_value),
                                  np.asarray(b.action_value))
    assert int(a.best_action) == int(b.best_action)
    if a.tree is not None:
        _assert_same(a.tree, b.tree)
    for k in a.stats:
        assert int(a.stats[k]) == int(b.stats[k]), k


@pytest.mark.parametrize("method", ("tree", "pipeline"))
@pytest.mark.parametrize("lanes", (4, 8))
def test_mega_equals_lockstep_at_wave_widths(method, lanes):
    """On the uint32-hash PGame domain the vectorized expand is bitwise the
    scanned expand even at real wave widths — not just statistically."""
    a = _run(method, "lockstep", lanes, budget=128)
    b = _run(method, "mega", lanes, budget=128)
    _assert_same(a.tree, b.tree)
    for k in a.stats:
        assert int(a.stats[k]) == int(b.stats[k]), k


@pytest.mark.parametrize("lanes", (1, 8))
def test_mega_invariants(lanes):
    res = _run("pipeline", "mega", lanes, budget=128)
    c = check_consistency(res.tree)
    assert bool(c["vloss_drained"]), c
    assert bool(c["unobs_drained"]), c
    assert bool(c["visit_flow"]), c
    assert bool(c["parents_valid"]), c
    assert int(res.tree.visits[ROOT]) == 128
