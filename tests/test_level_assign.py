"""Within-level running assignment (``level_assign="running"``, DESIGN.md §16).

The decorrelation ISSUE's acceptance bar, proven four ways:

* lanes=1 bitwise parity — with a single lane the running delta is
  identically zero, so running-lockstep (and running-mega) reproduce the
  lane-major scan bit-for-bit for all five strategies, in both vl modes;
* the three implementations agree — the jnp reference scan, the Pallas
  ``uct_argmax_running`` kernel (interpret mode), and the megakernel's
  fused per-level loop are bit-identical on the same level boards,
  including duplicated-parent rows and ragged valid masks;
* the knob threads end to end — SearchParams validation, SearchConfig
  forwarding, and MCTSDecodeConfig reach the per-token search;
* the behavior is real — at a co-located wave the running assignment
  strictly reduces within-level duplicate selections on a fixed seed,
  while the scan path (already decorrelated by construction) is a no-op.

Post-run invariants (both in-flight planes drained) ride along.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stages as S
from repro.core import uct
from repro.core.domains.pgame import PGameDomain
from repro.core.tree import check_consistency, init_tree
from repro.kernels.search_wave import ops, ref
from repro.kernels.uct_select import ops as uops
from repro.search import SearchConfig, SearchParams, search

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=3)
ALL_METHODS = ("sequential", "root", "leaf", "tree", "pipeline")
PLANES = ("visits", "value", "vloss", "unobs", "children", "parent",
          "action", "prior", "terminal", "next_free", "free_top")


def _assert_same_arena(ta, tb, msg=""):
    for f in PLANES:
        np.testing.assert_array_equal(np.asarray(getattr(ta, f)),
                                      np.asarray(getattr(tb, f)),
                                      err_msg=f"{msg}{f}")


def _run(method, ws, lanes, seed=0, budget=64, vl_mode="wu",
         la="running"):
    sp = SearchParams(cp=0.7, max_depth=6, wave_select=ws, kernels="ref",
                      vl_mode=vl_mode, level_assign=la)
    cfg = SearchConfig(method=method, budget=budget, lanes=lanes, params=sp)
    return jax.jit(lambda r: search(DOM, cfg, r))(jax.random.key(seed))


def _assert_same_result(a, b):
    np.testing.assert_array_equal(np.asarray(a.action_visits),
                                  np.asarray(b.action_visits))
    np.testing.assert_array_equal(np.asarray(a.action_value),
                                  np.asarray(b.action_value))
    if a.tree is not None and b.tree is not None:   # root keeps no tree
        for k in ("visits", "value", "children", "vloss", "unobs"):
            np.testing.assert_array_equal(np.asarray(getattr(a.tree, k)),
                                          np.asarray(getattr(b.tree, k)),
                                          err_msg=k)
    for k in a.stats:
        assert int(a.stats[k]) == int(b.stats[k]), k


# ---------------------------------------------------------------------------
# lanes=1: the running delta is identically zero -> bitwise == scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", ALL_METHODS)
@pytest.mark.parametrize("ws", ("lockstep", "mega"))
def test_running_lanes1_bitwise_equals_scan(method, ws):
    a = _run(method, "scan", 1, la="independent")
    b = _run(method, ws, 1, la="running")
    _assert_same_result(a, b)


@pytest.mark.parametrize("method", ("tree", "pipeline"))
@pytest.mark.parametrize("ws", ("lockstep", "mega"))
def test_running_lanes1_bitwise_equals_scan_loss_mode(method, ws):
    a = _run(method, "scan", 1, vl_mode="loss", la="independent")
    b = _run(method, ws, 1, vl_mode="loss", la="running")
    _assert_same_result(a, b)


def test_scan_path_ignores_level_assign():
    """The lane-major scan already sees earlier lanes' in-flight marks
    through the plane itself, so the knob is a documented no-op there."""
    for vl_mode in ("loss", "wu"):
        a = _run("pipeline", "scan", 4, vl_mode=vl_mode, la="independent")
        b = _run("pipeline", "scan", 4, vl_mode=vl_mode, la="running")
        _assert_same_result(a, b)


# ---------------------------------------------------------------------------
# the three implementations agree on one level board
# ---------------------------------------------------------------------------
def _wave_board(seed, lanes, a, groups=3):
    """A lockstep level board: ``groups`` distinct parents, lanes co-located
    round-robin so every group repeats identical child-stat rows."""
    ks = jax.random.split(jax.random.key(seed), 6)
    gn = jax.random.randint(ks[0], (groups, a), 0, 50).astype(jnp.float32)
    gw = jax.random.normal(ks[1], (groups, a)) * 3
    gv = jax.random.randint(ks[2], (groups, a), 0, 3).astype(jnp.float32)
    go = jax.random.randint(ks[3], (groups, a), 0, 4).astype(jnp.float32)
    gva = jax.random.bernoulli(ks[4], 0.8, (groups, a)).at[:, 0].set(True)
    rows = (jnp.arange(lanes) % groups).astype(jnp.int32)
    n, w, vl, o, valid = gn[rows], gw[rows], gv[rows], go[rows], gva[rows]
    pn = n.sum(-1) + vl.sum(-1) + o.sum(-1) + 1
    return n, w, vl, o, pn, valid, rows


@pytest.mark.parametrize("vl_mode", ("loss", "wu"))
@pytest.mark.parametrize("lanes", (1, 4, 8, 16))
def test_running_jnp_ref_equals_pallas_interpret(vl_mode, lanes):
    n, w, vl, o, pn, valid, rows = _wave_board(21 + lanes, lanes, 5)
    kw = dict(valid=valid, child_o=o, vl_mode=vl_mode)
    a1 = uct.uct_argmax_running(n, w, vl, pn, rows, 1.1, **kw)
    a2 = uops.uct_argmax_running(n, w, vl, pn, rows, cp=1.1,
                                 interpret=True, **kw)
    assert bool((a1 == a2).all())


@pytest.mark.parametrize("vl_mode", ("loss", "wu"))
def test_running_lanes1_equals_independent_argmax(vl_mode):
    n, w, vl, o, pn, valid, rows = _wave_board(33, 1, 6)
    kw = dict(valid=valid, child_o=o, vl_mode=vl_mode)
    a = uct.uct_argmax(n, w, vl, pn, 1.4, **kw)
    b = uct.uct_argmax_running(n, w, vl, pn, rows, 1.4, **kw)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_running_spreads_colocated_unvisited_siblings():
    """The dispersion contract: co-located lanes at a parent with >= lanes
    idle unvisited children take DISTINCT children (each pick knocks its
    must-explore sentinel out for the rest of the wave), where the
    independent assignment stacks every lane on one child."""
    lanes, a = 4, 6
    n = jnp.zeros((lanes, a))                      # all unvisited
    w = jnp.zeros((lanes, a))
    vl = jnp.zeros((lanes, a))
    pn = jnp.ones((lanes,))
    valid = jnp.ones((lanes, a), bool)
    rows = jnp.zeros((lanes,), jnp.int32)          # one shared parent
    for vl_mode in ("loss", "wu"):
        kw = dict(valid=valid, child_o=vl, vl_mode=vl_mode)
        ind = np.asarray(uct.uct_argmax(n, w, vl, pn, 0.7, **kw))
        run = np.asarray(uct.uct_argmax_running(n, w, vl, pn, rows, 0.7,
                                                **kw))
        assert len(set(ind.tolist())) == 1          # stacked
        assert len(set(run.tolist())) == lanes      # spread
        pk = np.asarray(uops.uct_argmax_running(n, w, vl, pn, rows, cp=0.7,
                                                interpret=True, **kw))
        np.testing.assert_array_equal(pk, run)


# ---------------------------------------------------------------------------
# running megakernel (interpret) vs the ref fused wave, bit-for-bit
# ---------------------------------------------------------------------------
def _sp_run(vl_mode):
    return S.SearchParams(cp=0.7, max_depth=6, kernels="ref",
                          vl_mode=vl_mode, wave_select="lockstep",
                          level_assign="running")


def _scan_rounds(fn, lanes, rounds, seed, nodes=64):
    tree0 = init_tree(DOM, nodes)
    def body(tree, rng):
        tree, sel = fn(tree, lanes, rng)
        return tree, (sel["dup"].sum(), sel["dup_within"].sum(),
                      sel["dup_cross"].sum())
    rngs = jax.random.split(jax.random.key(seed), rounds)
    return jax.lax.scan(body, tree0, rngs)


@pytest.mark.parametrize("vl_mode", ("loss", "wu"))
@pytest.mark.parametrize("lanes", (1, 4, 8))
def test_running_pallas_interpret_round_bitwise_equals_ref(vl_mode, lanes):
    sp = _sp_run(vl_mode)
    ta, da = _scan_rounds(
        lambda t, l, r: ref.tree_round(t, DOM, sp, l, jnp.asarray(True), r),
        lanes, 6, 0)
    tb, db = _scan_rounds(
        lambda t, l, r: ops.tree_round(t, DOM, sp, l, jnp.asarray(True), r,
                                       impl="pallas", interpret=True),
        lanes, 6, 0)
    _assert_same_arena(ta, tb)
    for x, y in zip(da, db):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert bool((np.asarray(ta.vloss) == 0).all())
    assert bool((np.asarray(ta.unobs) == 0).all())


def _scan_ticks(fn, sp, lanes, ticks, seed, nodes=64):
    tree = init_tree(DOM, nodes)
    carry = (tree, S.empty_selection(sp, lanes),
             S.empty_expansion(sp, lanes, DOM),
             S.empty_playout(sp, lanes, DOM.num_actions))
    def body(c, inp):
        t, rng = inp
        tree, se, ep, pb = c
        tree, se, ep, pb = fn(tree, lanes, t < ticks - 3, se, ep, pb, rng)
        return (tree, se, ep, pb), (se["dup"].sum(), se["dup_within"].sum(),
                                    se["dup_cross"].sum())
    rngs = jax.random.split(jax.random.key(seed), ticks)
    (tree, *_), dups = jax.lax.scan(body, carry, (jnp.arange(ticks), rngs))
    return tree, dups


@pytest.mark.parametrize("vl_mode", ("loss", "wu"))
@pytest.mark.parametrize("lanes", (1, 4, 8))
def test_running_pallas_interpret_tick_bitwise_equals_ref(vl_mode, lanes):
    sp = _sp_run(vl_mode)
    ta, da = _scan_ticks(
        lambda t, l, wv, se, ep, pb, r:
            ref.pipeline_tick(t, DOM, sp, l, wv, se, ep, pb, r),
        sp, lanes, 9, 1)
    tb, db = _scan_ticks(
        lambda t, l, wv, se, ep, pb, r:
            ops.pipeline_tick(t, DOM, sp, l, wv, se, ep, pb, r,
                              impl="pallas", interpret=True),
        sp, lanes, 9, 1)
    _assert_same_arena(ta, tb)
    for x, y in zip(da, db):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert bool((np.asarray(ta.vloss) == 0).all())
    assert bool((np.asarray(ta.unobs) == 0).all())


# mega (fused ref round/tick) vs unfused lockstep at lanes > 1: the running
# loop inside the megernel's Select phase must track the staged jnp path
@pytest.mark.parametrize("method", ("tree", "pipeline"))
@pytest.mark.parametrize("lanes", (4, 8))
def test_running_mega_bitwise_equals_lockstep(method, lanes):
    a = _run(method, "lockstep", lanes)
    b = _run(method, "mega", lanes)
    _assert_same_result(a, b)


# ---------------------------------------------------------------------------
# the behavior: fewer within-level duplicates on a fixed seed; planes drain
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", ("tree", "pipeline"))
@pytest.mark.parametrize("ws", ("lockstep", "mega"))
def test_running_reduces_within_level_duplicates(method, ws):
    ind = _run(method, ws, 8, budget=96, la="independent")
    run = _run(method, ws, 8, budget=96, la="running")
    assert int(run.extras["dup_within"]) < int(ind.extras["dup_within"])
    # the headline stat is the UNION of the two flags (a lane can both share
    # a leaf within the wave and land on a pre-wave in-flight leaf), so the
    # split brackets it: max(parts) <= dup <= sum(parts)
    for res in (ind, run):
        dw, dc = int(res.extras["dup_within"]), int(res.extras["dup_cross"])
        d = int(res.stats["duplicates"])
        assert max(dw, dc) <= d <= dw + dc


@pytest.mark.parametrize("method", ("tree", "pipeline"))
@pytest.mark.parametrize("ws", ("lockstep", "mega"))
@pytest.mark.parametrize("vl_mode", ("loss", "wu"))
def test_running_drains_and_invariants(method, ws, vl_mode):
    res = _run(method, ws, 4, budget=96, vl_mode=vl_mode)
    c = check_consistency(res.tree)
    assert bool(c["unobs_drained"]), c
    assert bool(c["vloss_drained"]), c
    assert bool(c["visit_flow"]), c
    assert int(res.tree.visits[0]) == 96


# ---------------------------------------------------------------------------
# knob surface
# ---------------------------------------------------------------------------
def test_level_assign_validation_and_default():
    assert SearchParams().level_assign == "independent"
    assert not SearchParams().running
    assert SearchParams(level_assign="running").running
    with pytest.raises(ValueError, match="level_assign"):
        SearchParams(level_assign="nope")


def test_search_config_threads_level_assign():
    assert SearchConfig(level_assign="running").params.running
    # an explicit params knob wins over the config-level convenience knob
    sp = SearchParams(level_assign="running")
    assert SearchConfig(params=sp).params.running
    assert not SearchConfig().params.running


def test_mcts_decode_config_threads_level_assign():
    from repro.serving.mcts_decode import MCTSDecodeConfig
    cfg = MCTSDecodeConfig(level_assign="running")
    assert cfg.search_config().params.running
    assert not MCTSDecodeConfig().search_config().params.running
