"""Real multi-process ``jax.distributed`` CPU tests (DESIGN.md §13).

Two worker processes (4 forced host devices each -> an 8-device global
world) are launched via subprocess, initialize through
``compat.init_distributed_cpu`` (gloo CPU collectives), and run the
multi-host ``shard_search_batch`` path for real: global input placement via
``make_array_from_callback``, communication-free per-root programs, and the
cross-process all-gather of the results.  Every process asserts per-root
parity against single-process ``search`` — the same oracle as
tests/test_sharding.py — plus a killed-worker elastic run that completes
with only the victim's in-flight roots requeued.

The workers self-provision their devices, so this runs everywhere the
repo's other subprocess tests do (always-run in CI's chaos job).
"""
import os
import socket
import subprocess
import sys
import textwrap

WORKER = textwrap.dedent("""
    import os, sys
    pid, port = int(sys.argv[1]), sys.argv[2]
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    from repro.parallel.compat import init_distributed_cpu, mesh_is_multihost
    init_distributed_cpu(f"localhost:{port}", 2, pid)
    import numpy as np
    assert jax.process_count() == 2
    assert jax.device_count() == 8 and jax.local_device_count() == 4

    from repro.core.domains.pgame import PGameDomain
    from repro.launch.mesh import make_search_mesh
    from repro.search import (ElasticSearchDriver, FTSearchConfig,
                              SearchConfig, SearchParams, search,
                              search_batch, shard_search_batch)
    DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False,
                      seed=3)
    cfg = SearchConfig(method="pipeline", budget=32, lanes=4,
                       params=SearchParams(cp=0.7, max_depth=6),
                       keep_tree=False)
    rng = jax.random.key(7)

    # 1) multi-host shard_search_batch == single-process search, per root.
    #    B=5 exercises the padding contract across the host boundary.
    mesh = make_search_mesh()
    assert mesh_is_multihost(mesh)
    res = shard_search_batch([DOM] * 5, cfg, rng, mesh=mesh)
    keys = jax.random.split(rng, 5)
    for i in range(5):
        ind = search(DOM, cfg, keys[i])
        np.testing.assert_array_equal(np.asarray(res.action_visits[i]),
                                      np.asarray(ind.action_visits))
        np.testing.assert_allclose(np.asarray(res.action_value[i]),
                                   np.asarray(ind.action_value), rtol=1e-5)
        for k in res.stats:
            assert int(res.stats[k][i]) == int(ind.stats[k])
    print(pid, "PARITY OK", flush=True)

    # 2) killed-worker elastic run: logical host 1 (this job's second
    #    process share) dies launching roots [3, 4]; the run completes with
    #    ONLY those in-flight roots requeued, identical merged results on
    #    every process (the drivers run in deterministic lockstep).
    base = search_batch([DOM] * 6, cfg, rng, mesh=False)
    drv = ElasticSearchDriver(
        [DOM] * 6, cfg, rng,
        FTSearchConfig(hosts=2, chunk=2, watchdog_s=0.1,
                       kill_host_at_root=4))
    out = drv.run()
    np.testing.assert_array_equal(np.asarray(out.action_visits),
                                  np.asarray(base.action_visits))
    np.testing.assert_array_equal(np.asarray(out.action_value),
                                  np.asarray(base.action_value))
    assert drv.report.lost_hosts == [1], drv.report
    assert sorted(drv.report.requeued) == [3, 4], drv.report
    assert all(drv.report.runs[i] == (2 if i in (3, 4) else 1)
               for i in range(6)), drv.report
    print(pid, "KILLED-WORKER OK", flush=True)
""")


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def test_two_process_multihost_search():
    port = _free_port()
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
           "HOME": os.environ.get("HOME", "/root")}
    procs = [subprocess.Popen(
        [sys.executable, "-c", WORKER, str(pid), str(port)],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=env)
        for pid in (0, 1)]
    outs = []
    try:
        for p in procs:
            out, _ = p.communicate(timeout=600)
            outs.append(out)
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    for pid, (p, out) in enumerate(zip(procs, outs)):
        assert p.returncode == 0, f"worker {pid}:\n{out[-3000:]}"
        assert f"{pid} PARITY OK" in out
        assert f"{pid} KILLED-WORKER OK" in out
