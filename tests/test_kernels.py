"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_default_matmul_precision", "highest")


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,sq,sk,h,hkv,d,causal", [
    (2, 128, 128, 4, 2, 64, True),
    (1, 64, 64, 3, 3, 32, True),
    (2, 100, 100, 4, 1, 64, True),      # padding path
    (1, 96, 160, 2, 2, 128, False),     # cross-length, non-causal
    (1, 256, 256, 2, 1, 128, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, sq, sk, h, hkv, d, causal, dtype):
    from repro.kernels.flash_attention import ops as fa
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (b, sk, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, sk, hkv, d), dtype)
    o_ref = fa.flash_attention(q, k, v, causal=causal, use_ref=True)
    o_ker = fa.flash_attention(q, k, v, causal=causal, interpret=True,
                               blk_q=64, blk_k=64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o_ker, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,sk,h,hkv,d", [
    (2, 256, 4, 2, 64), (3, 1000, 4, 4, 32), (1, 512, 8, 1, 128),
])
def test_decode_attention(b, sk, h, hkv, d):
    from repro.kernels.decode_attention import ops as da
    ks = jax.random.split(jax.random.key(1), 4)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    k = jax.random.normal(ks[1], (b, sk, hkv, d))
    v = jax.random.normal(ks[2], (b, sk, hkv, d))
    vl = jax.random.randint(ks[3], (b,), 1, sk + 1)
    o_ref = da.decode_attention(q, k, v, vl, use_ref=True)
    o_ker = da.decode_attention(q, k, v, vl, interpret=True, blk_k=128)
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


# The shapes the cached MCTS decode path (models.transformer.step_fn via
# CachedLMDecodeDomain, DESIGN.md §10) actually issues: non-power-of-two
# cache rows sized prompt+depth+rollout, with per-row valid lengths
# ``pos + 1`` anywhere from a 1-token prefix up to the full row.  The kernel
# builds its compiler params through ``compat.tpu_compiler_params``, so these
# cases pass on jax 0.4.37 and latest alike.
@pytest.mark.parametrize("b,sk,h,hkv,d", [
    (4, 28, 4, 2, 8),       # test-size row: plen 16 + depth 8 + rollout 4
    (2, 44, 4, 2, 16),      # bench smoke row: plen 32 + depth 8 + rollout 4
    (3, 27, 3, 1, 32),      # odd row length, MQA grouping
])
def test_decode_attention_cached_domain_shapes(b, sk, h, hkv, d):
    from repro.kernels.decode_attention import ops as da
    ks = jax.random.split(jax.random.key(11), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    k = jax.random.normal(ks[1], (b, sk, hkv, d))
    v = jax.random.normal(ks[2], (b, sk, hkv, d))
    # ragged position offsets across the batch, pinning both extremes: the
    # first post-prefill step (valid 1 would mean an empty prefix; the domain
    # never goes below plen+1) and a row filled to capacity (valid == sk)
    base = np.linspace(1, sk, b).astype(np.int32)
    for vl in (jnp.asarray(base),
               jnp.full((b,), 1, jnp.int32),
               jnp.full((b,), sk, jnp.int32)):
        o_ref = da.decode_attention(q, k, v, vl, use_ref=True)
        o_ker = da.decode_attention(q, k, v, vl, interpret=True, blk_k=128)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   atol=2e-5, rtol=2e-5)


def test_decode_attention_matches_step_fn_issuance():
    """End-to-end shape check: the kernel path agrees with the ref oracle on
    the exact (q, cache, valid) stream a cached-domain rollout issues —
    sequential single-token steps with growing position offsets."""
    from repro.kernels.decode_attention import ops as da
    b, sk, h, hkv, d = 2, 20, 2, 1, 16
    ks = jax.random.split(jax.random.key(12), 3)
    k = jax.random.normal(ks[1], (b, sk, hkv, d))
    v = jax.random.normal(ks[2], (b, sk, hkv, d))
    plen = np.array([3, 7], np.int32)
    for step in range(4):                       # rollout_len=4 trajectory
        q = jax.random.normal(jax.random.fold_in(ks[0], step), (b, 1, h, d))
        vl = jnp.asarray(plen + 1 + step)       # valid = pos + 1
        o_ref = da.decode_attention(q, k, v, vl, use_ref=True)
        o_ker = da.decode_attention(q, k, v, vl, interpret=True, blk_k=128)
        np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# wkv6 (rwkv6 recurrence)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,t,h,n,chunk", [
    (2, 64, 2, 16, 16), (1, 100, 3, 8, 32), (2, 48, 4, 32, 16),
])
def test_wkv6_kernel(b, t, h, n, chunk):
    from repro.kernels.rwkv6_scan import kernel as K, ref as R
    ks = jax.random.split(jax.random.key(2), 5)
    r = jax.random.normal(ks[0], (b, t, h, n)) * 0.5
    k = jax.random.normal(ks[1], (b, t, h, n)) * 0.5
    v = jax.random.normal(ks[2], (b, t, h, n)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n))) * 0.2 + 0.8
    u = jax.random.normal(ks[4], (h, n)) * 0.3
    st = jax.random.normal(jax.random.key(9), (b, h, n, n)) * 0.1
    y1, s1 = R.wkv6_ref(r, k, v, w, u, st)
    y2, s2 = K.wkv6_pallas(r, k, v, w, u, st, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s1), atol=2e-4, rtol=2e-4)


def test_wkv6_chunked_ops_matches_sequential():
    from repro.kernels.rwkv6_scan import ops, ref
    ks = jax.random.split(jax.random.key(3), 5)
    b, t, h, n = 2, 40, 2, 8
    r = jax.random.normal(ks[0], (b, t, h, n)) * 0.5
    k = jax.random.normal(ks[1], (b, t, h, n)) * 0.5
    v = jax.random.normal(ks[2], (b, t, h, n)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n))) * 0.3 + 0.7
    u = jax.random.normal(ks[4], (h, n)) * 0.3
    st = jnp.zeros((b, h, n, n))
    y1, s1 = ref.wkv6_ref(r, k, v, w, u, st)
    y2, s2 = ops.wkv6_chunked(r, k, v, w, u, st, chunk=16)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s1), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# ssd (mamba2 recurrence)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,t,h,p,n,chunk", [
    (2, 64, 2, 16, 8, 16), (1, 96, 4, 8, 16, 32), (2, 80, 2, 32, 64, 16),
])
def test_ssd_kernel(b, t, h, p, n, chunk):
    from repro.kernels.ssm_scan import kernel as K, ref as R
    ks = jax.random.split(jax.random.key(4), 5)
    x = jax.random.normal(ks[0], (b, t, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, t, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, t, n)) * 0.5
    D = jnp.ones((h,)) * 0.5
    st = jax.random.normal(jax.random.key(8), (b, h, p, n)) * 0.1
    y1, s1 = R.ssd_ref(x, dt, A, Bm, Cm, D, st)
    y2, s2 = K.ssd_pallas(x, dt, A, Bm, Cm, D, st, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s1), atol=2e-4, rtol=2e-4)


def test_ssd_chunked_ops_matches_sequential():
    from repro.kernels.ssm_scan import ops, ref
    ks = jax.random.split(jax.random.key(5), 5)
    b, t, h, p, n = 2, 50, 2, 8, 8
    x = jax.random.normal(ks[0], (b, t, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, t, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, t, n)) * 0.5
    D = jnp.zeros((h,))
    st = jnp.zeros((b, h, p, n))
    y1, s1 = ref.ssd_ref(x, dt, A, Bm, Cm, D, st)
    y2, s2 = ops.ssd_chunked(x, dt, A, Bm, Cm, D, st, chunk=16)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s1), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# uct_select
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("r,a", [(7, 4), (300, 8), (64, 130), (1, 2)])
def test_uct_argmax_kernel(r, a):
    from repro.kernels.uct_select import ops as uo
    ks = jax.random.split(jax.random.key(6), 4)
    n = jax.random.randint(ks[0], (r, a), 0, 50).astype(jnp.float32)
    w = jax.random.normal(ks[1], (r, a)) * 3
    vl = jax.random.randint(ks[2], (r, a), 0, 3).astype(jnp.float32)
    pn = n.sum(-1) + 1
    valid = jax.random.bernoulli(ks[3], 0.8, (r, a)).at[:, 0].set(True)
    a1 = uo.uct_argmax(n, w, vl, pn, cp=1.4, valid=valid, use_ref=True)
    a2 = uo.uct_argmax(n, w, vl, pn, cp=1.4, valid=valid, interpret=True)
    assert bool((a1 == a2).all())


# The row shapes the lockstep Select stage issues (DESIGN.md §11): one
# [lanes, A] launch per tree level, where many rows repeat the SAME parent's
# child stats (co-located lanes), ``valid`` is ragged across rows, and
# finished lanes contribute all-invalid rows.
@pytest.mark.parametrize("lanes,a", [(8, 4), (12, 4), (16, 8), (32, 130)])
def test_uct_argmax_kernel_wave_duplicated_parents(lanes, a):
    from repro.kernels.uct_select import ops as uo
    ks = jax.random.split(jax.random.key(13), 4)
    # 3 distinct parents, each duplicated over ceil(lanes/3) co-located lanes
    parents_n = jax.random.randint(ks[0], (3, a), 0, 50).astype(jnp.float32)
    parents_w = jax.random.normal(ks[1], (3, a)) * 3
    rows = jnp.arange(lanes) % 3
    n, w = parents_n[rows], parents_w[rows]
    vl = jax.random.randint(ks[2], (lanes, a), 0, 3).astype(jnp.float32)
    pn = n.sum(-1) + 1
    valid = jax.random.bernoulli(ks[3], 0.7, (lanes, a)).at[:, 0].set(True)
    a1 = uo.uct_argmax(n, w, vl, pn, cp=1.4, valid=valid, use_ref=True)
    a2 = uo.uct_argmax(n, w, vl, pn, cp=1.4, valid=valid, interpret=True)
    assert bool((a1 == a2).all())
    # identical rows with identical masks pick identical children
    same = np.asarray(rows[:, None] == rows[None, :])
    eq_mask = np.asarray((valid[:, None, :] == valid[None, :, :]).all(-1))
    eq_vl = np.asarray((vl[:, None, :] == vl[None, :, :]).all(-1))
    out = np.asarray(a2)
    ii, jj = np.nonzero(same & eq_mask & eq_vl)
    assert (out[ii] == out[jj]).all()


def test_uct_argmax_kernel_wave_finished_lanes():
    """All-invalid rows (finished/masked lanes) return 0 on both paths, and
    an entirely-finished wave — the all-lanes-done edge — is well defined."""
    from repro.kernels.uct_select import ops as uo
    lanes, a = 8, 4
    ks = jax.random.split(jax.random.key(14), 3)
    n = jax.random.randint(ks[0], (lanes, a), 0, 9).astype(jnp.float32)
    w = jax.random.normal(ks[1], (lanes, a))
    vl = jnp.zeros((lanes, a))
    pn = n.sum(-1) + 1
    half = jnp.arange(lanes)[:, None] < 4        # lanes 4.. are finished
    valid = jnp.broadcast_to(half, (lanes, a))
    a1 = uo.uct_argmax(n, w, vl, pn, cp=1.4, valid=valid, use_ref=True)
    a2 = uo.uct_argmax(n, w, vl, pn, cp=1.4, valid=valid, interpret=True)
    assert bool((a1 == a2).all())
    assert bool((a2[4:] == 0).all())
    none = jnp.zeros((lanes, a), bool)
    z1 = uo.uct_argmax(n, w, vl, pn, cp=1.4, valid=none, use_ref=True)
    z2 = uo.uct_argmax(n, w, vl, pn, cp=1.4, valid=none, interpret=True)
    assert bool((z1 == 0).all()) and bool((z2 == 0).all())


# Must-explore sentinel ordering (uct.py docstring): idle unvisited children
# score 1e30 and win; sentinel ties resolve FIRST-MAX — the lowest valid
# index — identically on the ref and Pallas paths, in both vl modes.
@pytest.mark.parametrize("vl_mode", ["loss", "wu"])
@pytest.mark.parametrize("r,a", [(8, 4), (64, 130)])
def test_uct_argmax_multiple_unvisited_tie_lowest_index(vl_mode, r, a):
    from repro.kernels.uct_select import ops as uo
    ks = jax.random.split(jax.random.key(15), 3)
    n = jax.random.randint(ks[0], (r, a), 0, 9).astype(jnp.float32)
    # every row gets >= 2 idle unvisited children at random columns
    cols = jax.random.permutation(
        ks[1], jnp.broadcast_to(jnp.arange(a), (r, a)), axis=1,
        independent=True)[:, :2]
    rows = jnp.arange(r)[:, None]
    n = n.at[rows, cols].set(0.0)
    w = jax.random.normal(ks[2], (r, a))
    zero = jnp.zeros((r, a))
    pn = n.sum(-1) + 1
    valid = jnp.ones((r, a), bool)
    kw = dict(cp=1.4, valid=valid, child_o=zero, vl_mode=vl_mode)
    a1 = uo.uct_argmax(n, w, zero, pn, use_ref=True, **kw)
    a2 = uo.uct_argmax(n, w, zero, pn, interpret=True, **kw)
    assert bool((a1 == a2).all())
    # first-max: the winner is the LOWEST-index unvisited child
    expect = np.asarray(jnp.argmax(n == 0.0, axis=-1))
    assert (np.asarray(a2) == expect).all()
    # masking the lowest unvisited column moves the tie to the next one
    valid2 = valid.at[rows[:, 0], expect].set(False)
    kw["valid"] = valid2
    b1 = uo.uct_argmax(n, w, zero, pn, use_ref=True, **kw)
    b2 = uo.uct_argmax(n, w, zero, pn, interpret=True, **kw)
    assert bool((b1 == b2).all())
    assert not (np.asarray(b2) == expect).any()


@pytest.mark.parametrize("r,a", [(7, 4), (300, 8), (64, 130), (1, 2)])
def test_uct_argmax_kernel_wu_mode(r, a):
    """WU-UCT scoring (vl_mode="wu"): the O operand feeds exploration only.
    Ref and Pallas agree bit-for-bit; the vloss operand is ignored; with
    O == 0 the wu ranking falls back to loss-with-no-vloss exactly."""
    from repro.kernels.uct_select import ops as uo
    ks = jax.random.split(jax.random.key(16), 5)
    n = jax.random.randint(ks[0], (r, a), 0, 50).astype(jnp.float32)
    w = jax.random.normal(ks[1], (r, a)) * 3
    vl = jax.random.randint(ks[2], (r, a), 0, 3).astype(jnp.float32)
    o = jax.random.randint(ks[3], (r, a), 0, 5).astype(jnp.float32)
    pn = n.sum(-1) + 1 + o.sum(-1)
    valid = jax.random.bernoulli(ks[4], 0.8, (r, a)).at[:, 0].set(True)
    kw = dict(cp=1.4, valid=valid, vl_mode="wu")
    a1 = uo.uct_argmax(n, w, vl, pn, child_o=o, use_ref=True, **kw)
    a2 = uo.uct_argmax(n, w, vl, pn, child_o=o, interpret=True, **kw)
    assert bool((a1 == a2).all())
    # vloss never reaches the wu formula
    a3 = uo.uct_argmax(n, w, vl * 0, pn, child_o=o, interpret=True, **kw)
    assert bool((a2 == a3).all())
    # O == 0 and vloss == 0: both modes compute the same scores
    z = jnp.zeros((r, a))
    wu0 = uo.uct_argmax(n, w, z, pn, child_o=z, interpret=True, **kw)
    ls0 = uo.uct_argmax(n, w, z, pn, cp=1.4, valid=valid, interpret=True)
    assert bool((wu0 == ls0).all())


def test_uct_argmax_kernel_wu_all_masked_rows():
    """The all-lanes-done edge under wu mode: fully-masked rows return 0 on
    both paths, matching the loss-mode contract."""
    from repro.kernels.uct_select import ops as uo
    lanes, a = 8, 4
    ks = jax.random.split(jax.random.key(17), 3)
    n = jax.random.randint(ks[0], (lanes, a), 0, 9).astype(jnp.float32)
    w = jax.random.normal(ks[1], (lanes, a))
    o = jax.random.randint(ks[2], (lanes, a), 0, 4).astype(jnp.float32)
    pn = n.sum(-1) + 1 + o.sum(-1)
    none = jnp.zeros((lanes, a), bool)
    kw = dict(cp=1.4, valid=none, child_o=o, vl_mode="wu")
    z1 = uo.uct_argmax(n, w, o * 0, pn, use_ref=True, **kw)
    z2 = uo.uct_argmax(n, w, o * 0, pn, interpret=True, **kw)
    assert bool((z1 == 0).all()) and bool((z2 == 0).all())


# Running-assignment kernel (DESIGN.md §16): the fori_loop scan over rows
# must agree with the jnp reference on the exact boards the lockstep Select
# stage issues — duplicated parents, ragged/odd row counts (the 8-row pad
# path), finished lanes interleaved with active ones, and sentinel ties.
@pytest.mark.parametrize("vl_mode", ["loss", "wu"])
@pytest.mark.parametrize("lanes,a", [(7, 4), (8, 4), (12, 8), (16, 130)])
def test_uct_argmax_running_kernel_duplicated_parents(vl_mode, lanes, a):
    from repro.kernels.uct_select import ops as uo
    ks = jax.random.split(jax.random.key(18), 5)
    gn = jax.random.randint(ks[0], (3, a), 0, 50).astype(jnp.float32)
    gw = jax.random.normal(ks[1], (3, a)) * 3
    gv = jax.random.randint(ks[2], (3, a), 0, 3).astype(jnp.float32)
    go = jax.random.randint(ks[3], (3, a), 0, 4).astype(jnp.float32)
    rows = (jnp.arange(lanes) % 3).astype(jnp.int32)
    n, w, vl, o = gn[rows], gw[rows], gv[rows], go[rows]
    pn = n.sum(-1) + vl.sum(-1) + o.sum(-1) + 1
    valid = jax.random.bernoulli(ks[4], 0.7, (3, a)).at[:, 0].set(True)[rows]
    kw = dict(cp=1.4, valid=valid, child_o=o, vl_mode=vl_mode)
    a1 = uo.uct_argmax_running(n, w, vl, pn, rows, use_ref=True, **kw)
    a2 = uo.uct_argmax_running(n, w, vl, pn, rows, interpret=True, **kw)
    assert bool((a1 == a2).all())


@pytest.mark.parametrize("vl_mode", ["loss", "wu"])
def test_uct_argmax_running_kernel_skips_finished_lanes(vl_mode):
    """Finished (all-invalid) lanes interleaved with active co-located ones:
    they return 0 AND contribute nothing to later lanes' deltas — the active
    lanes still take distinct unvisited children as if the wave were dense.
    The entirely-finished wave returns all zeros on both paths."""
    from repro.kernels.uct_select import ops as uo
    lanes, a = 8, 6
    z = jnp.zeros((lanes, a))
    pn = jnp.ones((lanes,))
    act = (jnp.arange(lanes) % 2) == 0            # lanes 1,3,5,7 finished
    valid = jnp.broadcast_to(act[:, None], (lanes, a))
    rows = jnp.zeros((lanes,), jnp.int32)         # one shared parent
    kw = dict(cp=0.7, valid=valid, child_o=z, vl_mode=vl_mode)
    a1 = uo.uct_argmax_running(z, z, z, pn, rows, use_ref=True, **kw)
    a2 = uo.uct_argmax_running(z, z, z, pn, rows, interpret=True, **kw)
    assert bool((a1 == a2).all())
    out = np.asarray(a2)
    assert (out[1::2] == 0).all()
    # active lanes disperse over the unvisited children, skipping the holes
    assert sorted(out[::2].tolist()) == [0, 1, 2, 3]
    none = jnp.zeros((lanes, a), bool)
    kw["valid"] = none
    z1 = uo.uct_argmax_running(z, z, z, pn, rows, use_ref=True, **kw)
    z2 = uo.uct_argmax_running(z, z, z, pn, rows, interpret=True, **kw)
    assert bool((z1 == 0).all()) and bool((z2 == 0).all())


@pytest.mark.parametrize("vl_mode", ["loss", "wu"])
def test_uct_argmax_running_must_explore_sentinel_rotates(vl_mode):
    """Sentinel ties under the running delta: the first lane of a co-located
    pair takes the LOWEST-index idle unvisited child (first-max); its pick
    raises that child's effective count past the 0.5 threshold, so the
    second lane's sentinel moves to the OTHER unvisited child."""
    from repro.kernels.uct_select import ops as uo
    a = 5
    unv = {0: (1, 3), 1: (0, 4)}                  # parent -> unvisited cols
    gn = np.full((2, a), 7.0, np.float32)
    for p, cols in unv.items():
        gn[p, list(cols)] = 0.0
    rows = jnp.asarray([0, 0, 1, 1], jnp.int32)
    n = jnp.asarray(gn)[rows]
    w = jnp.asarray(np.random.default_rng(19).normal(size=(2, a)),
                    jnp.float32)[rows]
    z = jnp.zeros((4, a))
    pn = n.sum(-1) + 1
    valid = jnp.ones((4, a), bool)
    kw = dict(cp=1.4, valid=valid, child_o=z, vl_mode=vl_mode)
    a1 = uo.uct_argmax_running(n, w, z, pn, rows, use_ref=True, **kw)
    a2 = uo.uct_argmax_running(n, w, z, pn, rows, interpret=True, **kw)
    assert bool((a1 == a2).all())
    assert np.asarray(a2).tolist() == [1, 3, 0, 4]


# ---------------------------------------------------------------------------
# flash backward (custom VJP) vs autodiff-through-sdpa
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cap", [0.0, 30.0])
@pytest.mark.parametrize("dv", [16, 8])
def test_blocked_attention_grads(cap, dv):
    from repro.models import layers as L
    ks = jax.random.split(jax.random.key(7), 4)
    b, s, h, hkv, d = 2, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, dv))
    t = jax.random.normal(ks[3], (b, s, h, dv))
    f1 = lambda q, k, v: (L.sdpa(q, k, v, causal=True, logits_soft_cap=cap) * t).sum()
    f2 = lambda q, k, v: (L.blocked_attention(
        q, k, v, causal=True, blk_q=32, blk_k=16, logits_soft_cap=cap) * t).sum()
    o1, g1 = jax.value_and_grad(f1, argnums=(0, 1, 2))(q, k, v)
    o2, g2 = jax.value_and_grad(f2, argnums=(0, 1, 2))(q, k, v)
    assert abs(float(o1 - o2)) < 1e-3
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(b_), np.asarray(a), atol=1e-4, rtol=1e-3)
