"""checkpoint/store.py unit coverage (ISSUE 7 satellite): atomic save under
injected kills, keep-N pruning, and restore-latest of search-result pytrees.
The module had never been exercised by tier-1 before the elastic driver
started committing per-root results through it (DESIGN.md §13)."""
import os

import numpy as np
import pytest

from repro.checkpoint import store


def _result_tree(b=4, a=3, scale=1.0):
    """A search-result-shaped pytree (the elastic driver's commit payload)."""
    return {
        "done": np.array([True, False, True, False][:b]),
        "results": {
            "action_visits": (np.arange(b * a).reshape(b, a) * scale)
            .astype(np.int32),
            "action_value": np.linspace(0, scale, b * a, dtype=np.float32)
            .reshape(b, a),
            "best_action": np.arange(b, dtype=np.int32),
            "stats": {"playouts": np.full((b,), 32, np.int32),
                      "ticks": np.full((b,), 9, np.int32)},
        },
    }


def _like(tree):
    import jax
    return jax.tree_util.tree_map(lambda x: np.zeros_like(x), tree)


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _result_tree()
    store.save(d, 1, tree)
    assert store.latest_step(d) == 1
    out = store.restore(d, 1, _like(tree))
    import jax
    jax.tree_util.tree_map(np.testing.assert_array_equal, out, tree)


def test_bf16_leaves_roundtrip(tmp_path):
    ml_dtypes = pytest.importorskip("ml_dtypes")
    d = str(tmp_path)
    tree = {"x": np.arange(6, dtype=np.float32).astype(ml_dtypes.bfloat16)}
    store.save(d, 1, tree)
    out = store.restore(d, 1, {"x": np.zeros(6, ml_dtypes.bfloat16)})
    assert out["x"].dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(out["x"].astype(np.float32),
                                  tree["x"].astype(np.float32))


def test_kill_mid_write_never_tears_the_latest(tmp_path, monkeypatch):
    """An injected kill mid-save leaves the previous checkpoint committed and
    readable; the half-written step is invisible and a retry succeeds."""
    d = str(tmp_path)
    t1 = _result_tree(scale=1.0)
    t2 = _result_tree(scale=2.0)
    store.save(d, 1, t1)

    real_save = np.save
    calls = {"n": 0}

    def dying_save(path, arr, **kw):
        calls["n"] += 1
        if calls["n"] == 2:                 # die after the first leaf lands
            raise KeyboardInterrupt("injected kill mid-write")
        return real_save(path, arr, **kw)

    monkeypatch.setattr(np, "save", dying_save)
    with pytest.raises(KeyboardInterrupt):
        store.save(d, 2, t2)
    monkeypatch.setattr(np, "save", real_save)

    # the torn step never became visible; step 1 is intact
    assert store.latest_step(d) == 1
    out = store.restore(d, 1, _like(t1))
    np.testing.assert_array_equal(out["results"]["action_visits"],
                                  t1["results"]["action_visits"])
    with pytest.raises(FileNotFoundError):
        store.restore(d, 2, _like(t2))
    # a retry of the same step commits cleanly over the stale tmp dir
    store.save(d, 2, t2)
    assert store.latest_step(d) == 2
    out2 = store.restore(d, 2, _like(t2))
    np.testing.assert_array_equal(out2["results"]["action_value"],
                                  t2["results"]["action_value"])


def test_kill_between_rename_and_commit_marker(tmp_path, monkeypatch):
    """Dying after the rename but before the COMMITTED marker leaves an
    uncommitted dir that latest_step/restore ignore, and a later save reaps."""
    d = str(tmp_path)
    store.save(d, 1, _result_tree())
    real_open = open
    step2 = os.path.join(d, "step_00000002")

    import builtins

    def dying_open(path, *a, **kw):
        if isinstance(path, str) and path == os.path.join(step2,
                                                          store.COMMITTED):
            raise KeyboardInterrupt("injected kill before commit marker")
        return real_open(path, *a, **kw)

    monkeypatch.setattr(builtins, "open", dying_open)
    with pytest.raises(KeyboardInterrupt):
        store.save(d, 2, _result_tree(scale=2.0))
    monkeypatch.setattr(builtins, "open", real_open)
    assert os.path.isdir(step2)                 # renamed, but not committed
    assert store.latest_step(d) == 1
    store.save(d, 3, _result_tree(scale=3.0))  # next save reaps the debris
    assert not os.path.isdir(step2)
    assert store.latest_step(d) == 3


def test_keep_n_pruning(tmp_path):
    d = str(tmp_path)
    for s in range(1, 6):
        store.save(d, s, _result_tree(scale=float(s)), keep=2)
    assert sorted(store._committed_steps(d)) == [4, 5]
    assert store.latest_step(d) == 5
    # the survivors are the two NEWEST and still restore correctly
    out = store.restore(d, 4, _like(_result_tree()))
    np.testing.assert_array_equal(
        out["results"]["action_visits"],
        _result_tree(scale=4.0)["results"]["action_visits"])


def test_stale_tmp_dirs_are_reaped(tmp_path):
    d = str(tmp_path)
    os.makedirs(os.path.join(d, "step_00000007.tmp"))
    store.save(d, 1, _result_tree())
    assert not os.path.exists(os.path.join(d, "step_00000007.tmp"))


def test_restore_structure_mismatch_raises(tmp_path):
    d = str(tmp_path)
    store.save(d, 1, _result_tree())
    with pytest.raises(ValueError, match="leaves"):
        store.restore(d, 1, {"just_one": np.zeros((4, 3), np.int32)})
    with pytest.raises(ValueError, match="shape mismatch"):
        bad = _result_tree()
        bad["results"]["action_visits"] = np.zeros((9, 9), np.int32)
        store.restore(d, 1, bad)


def test_manager_restore_latest(tmp_path):
    mgr = store.CheckpointManager(str(tmp_path), keep=3, every=1)
    tree = _result_tree()
    assert mgr.latest() is None
    step, state = mgr.restore_latest(_like(tree))
    assert step is None and state is None
    assert mgr.maybe_save(5, tree)
    mgr.wait()
    step, state = mgr.restore_latest(_like(tree))
    assert step == 5
    np.testing.assert_array_equal(state["done"], tree["done"])
