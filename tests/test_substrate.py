"""Substrate tests: checkpoint/restart, FT loop, elastic reshard, straggler
policy, optimizers/schedules, serving engine, collectives, pipeline parallel."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.configs import get_smoke_config
from repro.data import DataConfig, Prefetcher, make_batch_iterator
from repro.launch.steps import make_train_step
from repro.models.base import get_family
from repro.optim import adamw, lion, sgd
from repro.optim.schedules import cosine, wsd
from repro.runtime.ft import (FTConfig, SimulatedFailure, TrainerLoop,
                              run_with_restarts)
from repro.runtime.straggler import StragglerPolicy, simulate_throughput, wave_commit_mask


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    save(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    out = restore(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == np.dtype("bfloat16") or \
        str(out["b"]["c"].dtype) == "bfloat16"


def test_checkpoint_gc_and_async(tmp_path):
    tree = {"x": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        t = save(str(tmp_path), s, tree, asynchronous=True, keep=2)
        t.join()
    steps = sorted(int(n[5:]) for n in os.listdir(tmp_path)
                   if n.startswith("step_") and not n.endswith(".tmp"))
    assert steps == [3, 4]


def test_checkpoint_atomicity(tmp_path):
    tree = {"x": jnp.zeros(4)}
    save(str(tmp_path), 1, tree)
    # a stale tmp dir from a crashed save must not be visible
    os.makedirs(str(tmp_path / "step_00000002.tmp"))
    assert latest_step(str(tmp_path)) == 1


# ---------------------------------------------------------------------------
# FT trainer loop
# ---------------------------------------------------------------------------
def _make_loop(tmp_path, ft_kwargs=None, transient=False):
    cfg = get_smoke_config("smollm-135m")
    fam = get_family(cfg)
    opt = adamw()
    step_fn = jax.jit(make_train_step(cfg, opt, cosine(1e-3, 2, 50)))
    params = fam.init(cfg, jax.random.key(0))
    builds = {"n": 0}

    def factory():
        builds["n"] += 1
        kw = dict(ft_kwargs or {})
        if transient and builds["n"] > 1:
            kw.pop("fail_at_step", None)    # transient fault: does not recur
        ft = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=5, **kw)
        return TrainerLoop(
            step_fn, params, opt.init(params),
            lambda start: make_batch_iterator(
                cfg, DataConfig(seed=0, batch_size=2, seq_len=16), start),
            ft)
    return factory


def test_ft_restart_resumes_same_stream(tmp_path):
    factory = _make_loop(tmp_path, {"fail_at_step": 12}, transient=True)
    out = run_with_restarts(factory, n_steps=20, max_restarts=2)
    assert out["step"] == 20
    assert out["restarts"] == 1
    # reference run without failure gives the same final loss (determinism)
    factory2 = _make_loop(tmp_path / "ref")
    ref = factory2().run(20)
    assert abs(out["losses"][-1] - ref["losses"][-1]) < 1e-4


def test_ft_nan_skip(tmp_path):
    factory = _make_loop(tmp_path, {"nan_at_step": 3})
    loop = factory()
    out = loop.run(6)
    assert out["nan_skips"] == 1
    assert out["step"] == 6
    assert all(np.isfinite(l) for l in out["losses"])


def test_elastic_reshard_roundtrip(tmp_path):
    from repro.runtime.elastic import reshard_state
    from repro.launch.mesh import make_host_mesh
    cfg = get_smoke_config("smollm-135m")
    fam = get_family(cfg)
    params = fam.init(cfg, jax.random.key(0))
    opt = adamw()
    state = {"params": params, "opt": opt.init(params)}
    mesh = make_host_mesh(1, 1)
    out = reshard_state(cfg, state, mesh)
    for a, b in zip(jax.tree_util.tree_leaves(out["params"]),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# straggler policy
# ---------------------------------------------------------------------------
def test_straggler_commit_mask():
    lat = np.array([1.0, 1.1, 0.9, 25.0])
    keep, t = wave_commit_mask(lat, StragglerPolicy(deadline_factor=3.0))
    assert keep.tolist() == [True, True, True, False]
    assert t == 1.1


def test_straggler_speedup_under_heavy_tail():
    out = simulate_throughput(StragglerPolicy(), lanes=16, waves=200, tail=0.15)
    assert out["speedup"] > 1.3          # dropping tails buys real throughput
    assert out["drop_rate"] < 0.25


# ---------------------------------------------------------------------------
# optimizers / schedules
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("make_opt", [adamw, lion, sgd])
def test_optimizer_descends_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        upd, state = opt.update(g, state, params, jnp.float32(0.05))
        params = jax.tree_util.tree_map(lambda p, u: p + u, params, upd)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_wsd_schedule_phases():
    f = wsd(1.0, warmup=10, stable=20, decay=10)
    assert float(f(jnp.asarray(0))) == 0.0
    assert abs(float(f(jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(f(jnp.asarray(25))) - 1.0) < 1e-6
    assert float(f(jnp.asarray(40))) <= 0.02


# ---------------------------------------------------------------------------
# prefetcher
# ---------------------------------------------------------------------------
def test_prefetcher_order():
    it = Prefetcher(iter(range(10)), depth=3)
    assert list(it) == list(range(10))


# ---------------------------------------------------------------------------
# serving engine (continuous batching)
# ---------------------------------------------------------------------------
def test_serving_engine_batches_requests():
    from repro.serving.engine import EngineConfig, Request, ServingEngine
    cfg = get_smoke_config("qwen2-0.5b")
    fam = get_family(cfg)
    params = fam.init(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=3, max_seq=48))
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(uid=i, prompt=rng.integers(1, cfg.vocab_size, size=6),
                           max_new_tokens=5))
    out = eng.run_until_drained()
    assert out["tokens"] >= 5 * 4           # all requests progressed
    done = [s for s in eng.slots if s is not None and s.done]
    assert len(done) >= 1
    for r in done:
        assert len(r.out_tokens) == 5


def test_serving_matches_unbatched_decode():
    """Engine output for one request == greedy decode on the raw model."""
    from repro.serving.engine import EngineConfig, Request, ServingEngine
    cfg = get_smoke_config("smollm-135m")
    fam = get_family(cfg)
    params = fam.init(cfg, jax.random.key(0))
    prompt = np.array([5, 6, 7, 8])
    eng = ServingEngine(cfg, params, EngineConfig(max_batch=2, max_seq=32))
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    eng.run_until_drained()
    got = eng.slots[0].out_tokens
    # reference greedy
    toks = jnp.asarray(prompt, jnp.int32)[None]
    ref = []
    for _ in range(6):
        logits = fam.logits_fn(cfg, params, toks)
        t = int(jnp.argmax(logits[0, -1]))
        ref.append(t)
        toks = jnp.concatenate([toks, jnp.asarray([[t]], jnp.int32)], 1)
    assert got == ref
