"""MCTS core behaviour: paper schedule arithmetic, tree invariants,
pipeline vs sequential strength, baselines, domains — all search runs go
through the unified ``repro.search`` API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schedule
from repro.core.domains.pgame import (PGameDomain, enumerate_root_values,
                                      optimal_root_action)
from repro.core.tree import check_consistency
from repro.search import SearchConfig, SearchParams, search

DOM = PGameDomain(num_actions=4, game_depth=6, binary_reward=False, seed=3)
SP = SearchParams(cp=0.7, max_depth=6)


def _search(method, budget, lanes=1, seed=0):
    cfg = SearchConfig(method=method, budget=budget, lanes=lanes, params=SP)
    return jax.jit(lambda r: search(DOM, cfg, r))(jax.random.key(seed))


# ---------------------------------------------------------------------------
# paper's scheduling figures (the paper's only quantitative artifacts)
# ---------------------------------------------------------------------------
def test_fig3_linear_equal_stages():
    assert schedule.pipeline_makespan(4, (1, 1, 1, 1), lanes=1) == 7.0
    assert schedule.sequential_makespan(4) == 16.0


def test_fig4_unequal_stages():
    assert schedule.pipeline_makespan(4, (1, 1, 2, 1), lanes=1) == 11.0


def test_fig6_nonlinear_two_playout_lanes():
    assert schedule.pipeline_makespan(4, (1, 1, 2, 1), lanes=2) == 8.0


def test_steady_state_throughput():
    # slowest stage bounds throughput; lanes restore it (paper §V-C)
    assert schedule.steady_state_throughput((1, 1, 2, 1), 1) == 0.5
    assert schedule.steady_state_throughput((1, 1, 2, 1), 2) == 1.0


def test_makespan_monotone_in_lanes():
    base = schedule.pipeline_makespan(32, (1, 1, 4, 1), lanes=1)
    for lanes in (2, 4, 8):
        t = schedule.pipeline_makespan(32, (1, 1, 4, 1), lanes=lanes)
        assert t <= base
        base = t


# ---------------------------------------------------------------------------
# tree invariants
# ---------------------------------------------------------------------------
def _consistent(tree):
    c = check_consistency(tree)
    assert c["vloss_drained"], c
    assert c["visit_flow"], c
    assert c["parents_valid"], c


def test_sequential_invariants_and_strength():
    # budget 512: the top-2 oracle values are within 0.02, and at 256 the
    # recommendation still flips on some seeds (the seed repo's version of
    # this assertion was flaky for exactly that reason)
    res = _search("sequential", 512)
    _consistent(res.tree)
    assert int(res.tree["visits"][0]) == 512
    assert int(res.best_action) == optimal_root_action(DOM)


def test_pipeline_invariants():
    res = _search("pipeline", 128, lanes=4)
    _consistent(res.tree)
    assert int(res.stats["playouts"]) == 128
    assert float(res.extras["mean_occupancy"]) > 0.8   # pipeline keeps stages busy


def test_pipeline_linear_lanes1():
    res = _search("pipeline", 64, lanes=1, seed=1)
    _consistent(res.tree)
    assert int(res.stats["playouts"]) == 64


def test_tree_parallel_invariants():
    res = _search("tree", 128, lanes=8)
    _consistent(res.tree)
    assert int(res.stats["playouts"]) == 128


def test_leaf_parallel_runs():
    res = _search("leaf", 128, lanes=4)
    assert int(res.stats["playouts"]) == 128
    assert int(res.tree["visits"][0]) == 128          # aggregated backups


def test_root_parallel_combines():
    res = _search("root", 128, lanes=4)
    assert res.tree is None                            # no single shared tree
    assert int(res.action_visits.sum()) >= 124   # 4 workers x 32 - roots
    assert 0 <= int(res.best_action) < DOM.num_actions


# ---------------------------------------------------------------------------
# the paper's central claim: pipeline search overhead is bounded by the
# in-flight window, below tree parallelization at equal hardware concurrency
# ---------------------------------------------------------------------------
def test_pipeline_duplicates_bounded_vs_tree_parallel():
    lanes = 8
    budget = 256
    dup_pipe, dup_tp = [], []
    for s in range(3):
        st = _search("pipeline", budget, lanes=lanes, seed=s).stats
        dup_pipe.append(int(st["duplicates"]))
        st2 = _search("tree", budget, lanes=4 * lanes, seed=s).stats
        dup_tp.append(int(st2["duplicates"]))
    assert np.mean(dup_pipe) <= np.mean(dup_tp), (dup_pipe, dup_tp)


def test_pipeline_strength_tracks_sequential():
    """At equal budget, pipeline's recommended action matches the optimum
    about as often as sequential (strength-scalability, def. 2)."""
    # budget 384: this domain's top-2 actions are near-tied, and below ~384
    # playouts both searches still flip on several seeds (the seed repo's
    # budget of 192 made this latently flaky)
    budget, seeds = 384, 6
    opt = optimal_root_action(DOM)
    seq_hits = pipe_hits = 0
    seq_cfg = SearchConfig(method="sequential", budget=budget, params=SP)
    pipe_cfg = SearchConfig(method="pipeline", budget=budget, lanes=4, params=SP)
    seq_j = jax.jit(lambda r: search(DOM, seq_cfg, r).best_action)
    pipe_j = jax.jit(lambda r: search(DOM, pipe_cfg, r).best_action)
    for s in range(seeds):
        seq_hits += int(seq_j(jax.random.key(s))) == opt
        pipe_hits += int(pipe_j(jax.random.key(s))) == opt
    assert pipe_hits >= seq_hits - 2   # within noise at these budgets


# ---------------------------------------------------------------------------
# domains
# ---------------------------------------------------------------------------
def test_pgame_enumeration_matches_playouts():
    dom = PGameDomain(num_actions=3, game_depth=4, binary_reward=False, seed=7)
    vals = enumerate_root_values(dom)
    # Monte-Carlo estimate of the root values via domain.playout
    est = np.zeros(3)
    n = 1500
    for a in range(3):
        st = dom.step(dom.root_state(), jnp.int32(a))
        rngs = jax.random.split(jax.random.key(a), n)
        r = jax.vmap(lambda k: dom.playout(st, k))(rngs)
        est[a] = float(r.mean())
    np.testing.assert_allclose(est, vals, atol=0.03)


def test_lm_decode_domain():
    from repro.core.domains.lm_decode import LMDecodeDomain
    from repro.models.base import ModelConfig, get_family
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32", ce_chunk=8, remat=False)
    fam = get_family(cfg)
    params = fam.init(cfg, jax.random.key(0))
    dom = LMDecodeDomain(cfg=cfg, params=params,
                         prompt=jnp.array([1, 2, 3], jnp.int32),
                         num_actions=3, search_depth=3, rollout_len=2)
    st = dom.root_state()
    st2 = dom.step(st, jnp.int32(1))
    assert int(st2["len"]) == 4
    v = dom.playout(st2, jax.random.key(0))
    assert 0.0 < float(v) <= 1.0
    pri = dom.priors(st2)
    np.testing.assert_allclose(float(pri.sum()), 1.0, atol=1e-5)


def test_lm_decode_domain_padded_prompt_len():
    """A padded buffer + explicit prompt_len must match the exact-length
    domain's terminal horizon (the batched-serving contract)."""
    from repro.core.domains.lm_decode import LMDecodeDomain
    from repro.models.base import ModelConfig, get_family
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32", ce_chunk=8, remat=False)
    fam = get_family(cfg)
    params = fam.init(cfg, jax.random.key(0))
    buf = jnp.zeros((8,), jnp.int32).at[:3].set(jnp.array([1, 2, 3]))
    dom = LMDecodeDomain(cfg=cfg, params=params, prompt=buf, num_actions=3,
                         search_depth=2, rollout_len=1,
                         prompt_len=jnp.int32(3))
    st = dom.root_state()
    assert int(st["len"]) == 3
    assert not bool(dom.is_terminal(st))
    st = dom.step(dom.step(st, jnp.int32(0)), jnp.int32(1))
    assert bool(dom.is_terminal(st))
