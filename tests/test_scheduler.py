"""Request-scheduler state machine + serving-stats coverage (DESIGN.md §12).

Pure host-side: no model, no jax — the scheduler and stats are plain-Python
so every admission-order / preemption / budget invariant is exact and fast.
"""
import numpy as np
import pytest

from repro.serving.scheduler import (POLICIES, Admit, Evict, Request,
                                     RequestScheduler)
from repro.serving.stats import RequestTiming, Series, ServingStats, percentile


def _req(uid, plen=4, max_new=8, priority=0):
    return Request(uid=uid, prompt=np.arange(plen, dtype=np.int32),
                   max_new_tokens=max_new, priority=priority)


def _admits(events):
    return [e for e in events if isinstance(e, Admit)]


def _evicts(events):
    return [e for e in events if isinstance(e, Evict)]


# -- admission order ---------------------------------------------------------

def test_fcfs_admits_in_arrival_order():
    s = RequestScheduler(2, policy="fcfs")
    for uid, plen in ((0, 9), (1, 2), (2, 5)):
        s.submit(_req(uid, plen=plen))
    ev = s.schedule()
    assert [a.req.uid for a in _admits(ev)] == [0, 1]     # arrival order
    assert not _evicts(ev)
    assert s.pending() == 1


def test_spf_admits_shortest_prompt_first():
    s = RequestScheduler(2, policy="spf")
    for uid, plen in ((0, 9), (1, 2), (2, 5)):
        s.submit(_req(uid, plen=plen))
    ev = s.schedule()
    assert [a.req.uid for a in _admits(ev)] == [1, 2]     # 2 < 5 < 9
    assert s.pending() == 1


def test_spf_orders_by_effective_prefix_after_progress():
    # a requeued request's committed tokens count toward its prefill cost
    s = RequestScheduler(1, policy="spf")
    r = _req(0, plen=2)
    r.out_tokens.extend([7, 7, 7, 7])                     # effective len 6
    s.submit(r)
    s.submit(_req(1, plen=4))                             # effective len 4
    ev = s.schedule()
    assert _admits(ev)[0].req.uid == 1


@pytest.mark.parametrize("policy", POLICIES)
def test_priority_ranks_above_policy_order(policy):
    s = RequestScheduler(1, policy=policy)
    s.submit(_req(0, plen=1, priority=0))
    s.submit(_req(1, plen=9, priority=3))                 # longer AND later
    ev = s.schedule()
    assert _admits(ev)[0].req.uid == 1


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        RequestScheduler(2, policy="lifo")


# -- preemption / requeue ----------------------------------------------------

def test_preemption_evicts_lowest_priority_and_requeues():
    s = RequestScheduler(2)
    s.submit(_req(0, priority=1))
    s.submit(_req(1, priority=0))
    s.schedule()
    s.request(1).out_tokens.extend([5, 6])                # victim progress
    s.submit(_req(2, priority=5))
    ev = s.schedule()
    assert [e.req.uid for e in _evicts(ev)] == [1]        # lower prio loses
    assert [a.req.uid for a in _admits(ev)] == [2]
    # evicted request is requeued with committed tokens intact
    assert s.pending() == 1
    assert s._queue[0].uid == 1
    assert s._queue[0].out_tokens == [5, 6]


def test_preemption_requires_strictly_higher_priority():
    s = RequestScheduler(1)
    s.submit(_req(0, priority=2))
    s.schedule()
    s.submit(_req(1, priority=2))                         # equal: no preempt
    assert s.schedule() == []
    assert s.live() == [0]
    assert s.request(0).uid == 0


def test_requeued_request_keeps_fcfs_position():
    s = RequestScheduler(1, policy="fcfs")
    s.submit(_req(0, priority=0))
    s.schedule()
    s.submit(_req(1, priority=0))                         # waits behind 0
    s.submit(_req(2, priority=4))                         # preempts 0
    ev = s.schedule()
    assert _evicts(ev)[0].req.uid == 0
    assert _admits(ev)[0].req.uid == 2
    s.retire(0)                                           # uid2 finishes
    # uid0 kept its original arrival seq, so it re-admits BEFORE uid1
    ev = s.schedule()
    assert _admits(ev)[0].req.uid == 0


def test_preempt_admit_roundtrip_resumes_with_remaining_budget():
    s = RequestScheduler(1)
    s.submit(_req(0, max_new=8))
    s.schedule()
    for _ in range(3):
        s.request(0).out_tokens.append(9)
        s.on_token(0)
    s.submit(_req(1, priority=9, max_new=1))
    s.schedule()                                          # evicts uid0
    s.retire(0)
    ev = s.schedule()                                     # uid0 comes back
    a = _admits(ev)[0]
    assert a.req.uid == 0
    assert a.req.out_tokens == [9, 9, 9]
    assert int(s.remaining[a.slot]) == 5                  # 8 - 3 committed


def test_victim_is_lowest_priority_then_least_progress():
    s = RequestScheduler(3)
    for uid, prio in ((0, 1), (1, 0), (2, 0)):
        s.submit(_req(uid, priority=prio))
    s.schedule()
    s.request(1).out_tokens.extend([1, 2, 3])             # uid1 has progress
    s.submit(_req(3, priority=7))
    ev = s.schedule()
    # both uid1/uid2 are prio 0; uid2 has less progress -> cheaper to redo
    assert _evicts(ev)[0].req.uid == 2


# -- budgets -----------------------------------------------------------------

def test_budget_exhaustion_and_cap():
    s = RequestScheduler(1)
    s.submit(_req(0, max_new=3))
    ev = s.schedule()
    slot = _admits(ev)[0].slot
    s.cap_remaining(slot, 2)                              # engine capacity clamp
    assert not s.exhausted(slot)
    s.on_token(slot)
    assert not s.exhausted(slot)
    s.on_token(slot)
    assert s.exhausted(slot)


def test_retire_frees_slot_but_keeps_request_visible():
    s = RequestScheduler(1)
    s.submit(_req(0))
    s.schedule()
    s.retire(0)
    assert s.live() == []
    assert s.slots[0].uid == 0                            # still inspectable
    s.submit(_req(1))
    ev = s.schedule()
    assert _admits(ev)[0].slot == 0                       # slot was reusable


def test_schedule_is_idempotent_when_nothing_can_move():
    s = RequestScheduler(1)
    s.submit(_req(0))
    assert len(s.schedule()) == 1
    assert s.schedule() == []
    assert s.schedule() == []


# -- stats -------------------------------------------------------------------

def test_percentile_nearest_rank():
    xs = [float(v) for v in range(1, 11)]                 # 1..10
    assert percentile(xs, 50) == 5.0
    assert percentile(xs, 95) == 10.0
    assert percentile(xs, 0) == 1.0
    assert percentile([3.0], 99) == 3.0
    with pytest.raises(ValueError):
        percentile([], 50)


def test_series_summary():
    s = Series()
    for v in (1.0, 2.0, 3.0, 4.0):
        s.add(v)
    out = s.summary("x")
    assert out["x_mean"] == 2.5
    assert out["x_p50"] == 2.0
    assert Series().summary("y") == {}


def test_stats_lifecycle_with_fake_clock():
    t = [0.0]
    stats = ServingStats(clock=lambda: t[0])
    stats.on_submit(7, stats.now())
    t[0] = 1.0
    stats.on_admit(7, stats.now())
    t[0] = 3.0
    stats.on_token(7, stats.now())                        # first token
    t[0] = 4.0
    stats.on_token(7, stats.now())
    stats.on_preempt(7, stats.now())
    t[0] = 6.0
    stats.on_finish(7, stats.now())
    s = stats.requests[7].summary()
    assert s["queue_wait"] == 1.0
    assert s["ttft"] == 3.0
    assert s["latency"] == 6.0
    assert s["tokens"] == 2
    assert s["preemptions"] == 1
    assert s["done"]
    snap = stats.snapshot()
    assert snap["serving/requests_finished"] == 1.0
    assert snap["serving/preemptions"] == 1.0
    assert snap["serving/ttft_p50"] == 3.0
    assert snap["serving/wall_s"] == 6.0
    assert snap["serving/tokens_per_s"] == pytest.approx(2 / 6.0)


def test_stats_second_admission_keeps_first_queue_wait():
    t = [0.0]
    stats = ServingStats(clock=lambda: t[0])
    stats.on_submit(0, 0.0)
    t[0] = 2.0
    stats.on_admit(0, 2.0)
    t[0] = 5.0
    stats.on_admit(0, 5.0)                                # readmission
    assert stats.requests[0].admit_t == 2.0
    assert stats.admissions == 2
    assert stats.queue_wait.count == 1
