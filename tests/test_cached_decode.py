"""Cached-vs-uncached MCTS decode parity (DESIGN.md §10).

``CachedLMDecodeDomain`` must make the same decisions as the uncached
``LMDecodeDomain`` — token for token through the serving path, and
visit-for-visit at the search level — across every registered strategy,
for equal and ragged prompt lengths, on the plain and the mesh-sharded
paths.  The cached domain amortizes compute only; any behavioural drift is
a bug in the cache threading.
"""
import dataclasses
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_default_matmul_precision", "highest")

from repro.core.domains.lm_decode import (CachedLMDecodeDomain,  # noqa: E402
                                          LMDecodeDomain)
from repro.models.base import (ModelConfig, get_family,  # noqa: E402
                               seq_prefill, seq_step)
from repro.search import (SearchConfig, SearchParams, check_domain,  # noqa: E402
                          search)
from repro.serving import (EngineConfig, MCTSDecodeConfig, Request,  # noqa: E402
                           ServingEngine, mcts_decode_batch)

CFG = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                  dtype="float32", ce_chunk=8, remat=False)
METHODS = ("sequential", "root", "leaf", "tree", "pipeline")
EQUAL = np.array([[1, 2, 3], [4, 5, 6]], np.int32)
RAGGED = ([1, 2, 3, 4, 5], [7, 8])

multi = jax.device_count() >= 2
needs_mesh = pytest.mark.skipif(
    not multi, reason="needs >1 device (run in the CI multi-device job; the "
    "subprocess test below covers single-device sessions)")


@pytest.fixture(scope="module")
def params():
    return get_family(CFG).init(CFG, jax.random.key(0))


def _dcfg(method, cached):
    return MCTSDecodeConfig(method=method, num_actions=3, budget=6, lanes=2,
                            search_depth=2, rollout_len=2, cached=cached)


def test_cached_domain_satisfies_contract(params):
    dom = CachedLMDecodeDomain(cfg=CFG, params=params,
                               prompt=jnp.asarray([1, 2, 3], jnp.int32),
                               num_actions=3, search_depth=2, rollout_len=2)
    assert check_domain(dom)


@pytest.mark.parametrize("method", METHODS)
def test_search_level_parity(params, method):
    """Same visits, values, and recommended action for one search."""
    kw = dict(cfg=CFG, params=params,
              prompt=jnp.asarray([1, 2, 3, 4], jnp.int32),
              num_actions=3, search_depth=2, rollout_len=2)
    scfg = SearchConfig(method=method, budget=6, lanes=2, keep_tree=False,
                        params=SearchParams(cp=1.0, max_depth=2, puct=True))
    ru = search(LMDecodeDomain(**kw), scfg, jax.random.key(3))
    rc = search(CachedLMDecodeDomain(**kw), scfg, jax.random.key(3))
    np.testing.assert_array_equal(np.asarray(rc.action_visits),
                                  np.asarray(ru.action_visits))
    np.testing.assert_allclose(np.asarray(rc.action_value),
                               np.asarray(ru.action_value), atol=1e-5)
    assert int(rc.best_action) == int(ru.best_action)


@pytest.mark.parametrize("prompts", [EQUAL, RAGGED], ids=["equal", "ragged"])
@pytest.mark.parametrize("method", METHODS)
def test_decode_parity_token_for_token(params, method, prompts):
    """The serving path emits identical token streams cached and uncached,
    for equal-length and ragged prompt batches."""
    out_c = mcts_decode_batch(CFG, params, prompts, 2, _dcfg(method, True))
    out_u = mcts_decode_batch(CFG, params, prompts, 2, _dcfg(method, False))
    assert out_c == out_u


def test_generic_fallback_matches_family_step(params, monkeypatch):
    """With the dense family's prefill_fn/step_fn removed, the pure-JAX
    fallback (full forward from a token-buffer cache) produces the same
    logits — families without an incremental path stay correct."""
    from repro.models import transformer
    toks = jnp.zeros((10,), jnp.int32).at[:4].set(jnp.asarray([1, 2, 3, 4]))
    plen = jnp.int32(4)
    lg_f, cache_f = seq_prefill(CFG, params, toks, plen)
    monkeypatch.delattr(transformer, "prefill_fn")
    monkeypatch.delattr(transformer, "step_fn")
    lg_g, cache_g = seq_prefill(CFG, params, toks, plen)
    np.testing.assert_allclose(np.asarray(lg_g), np.asarray(lg_f), atol=1e-5)
    lg_g2, _ = seq_step(CFG, params, cache_g, jnp.int32(9), plen)
    monkeypatch.undo()
    lg_f2, _ = seq_step(CFG, params, cache_f, jnp.int32(9), plen)
    np.testing.assert_allclose(np.asarray(lg_g2), np.asarray(lg_f2), atol=1e-5)


def test_engine_slot_reuse_no_leak(params):
    """A request decoded after another request occupied (and reset) its slot
    emits the same tokens as when decoded alone.  Decisions of the LM domain
    are rng-independent (greedy rollouts), so any difference is state
    leaking across requests through the slot."""
    dcfg = _dcfg("pipeline", True)

    def run(prompts):
        eng = ServingEngine(CFG, params, EngineConfig(
            max_batch=1, max_seq=16, decode="mcts", mcts=dcfg))
        reqs = [Request(uid=i, prompt=np.asarray(p, np.int32),
                        max_new_tokens=2) for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs]

    alone = run([[9, 8, 7]])
    after_other = run([[1, 2, 3, 4, 5], [9, 8, 7]])
    assert after_other[1] == alone[0]


@needs_mesh
@pytest.mark.parametrize("prompts", ["equal", "ragged"])
def test_mesh_parity_cached_vs_uncached(params, prompts):
    """Cached == uncached on the auto-sharded multi-device path too, and the
    meshed cached stream matches the forced single-device vmap stream when B
    divides the mesh (same rng splits, DESIGN.md §9)."""
    b = jax.device_count()
    if prompts == "equal":
        batch = (np.arange(b * 3).reshape(b, 3) % 60 + 1).astype(np.int32)
    else:
        batch = [list(range(1, 2 + i % 3)) for i in range(b)]
    out_c = mcts_decode_batch(CFG, params, batch, 2, _dcfg("pipeline", True))
    out_u = mcts_decode_batch(CFG, params, batch, 2, _dcfg("pipeline", False))
    assert out_c == out_u
    out_v = mcts_decode_batch(CFG, params, batch, 2, _dcfg("pipeline", True),
                              mesh=False)
    assert out_c == out_v


def test_cached_parity_subprocess_8dev():
    """Single-device sessions: the mesh-sharded cached-vs-uncached parity on
    8 forced host devices (the pattern of tests/test_sharding.py)."""
    code = textwrap.dedent("""
        import dataclasses
        import jax, numpy as np
        jax.config.update("jax_default_matmul_precision", "highest")
        from repro.models.base import ModelConfig, get_family
        from repro.serving import MCTSDecodeConfig, mcts_decode_batch
        assert jax.device_count() == 8
        CFG = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                          n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                          dtype="float32", ce_chunk=8, remat=False)
        params = get_family(CFG).init(CFG, jax.random.key(0))
        dcfg = MCTSDecodeConfig(method="pipeline", num_actions=3, budget=6,
                                lanes=2, search_depth=2, rollout_len=2)
        # divisible B: meshed cached == meshed uncached == unmeshed cached
        eq = (np.arange(24).reshape(8, 3) % 60 + 1).astype(np.int32)
        c = mcts_decode_batch(CFG, params, eq, 1, dcfg)
        u = mcts_decode_batch(CFG, params, eq, 1,
                              dataclasses.replace(dcfg, cached=False))
        v = mcts_decode_batch(CFG, params, eq, 1, dcfg, mesh=False)
        assert c == u == v, (c, u, v)
        # ragged non-divisible B: pads to the mesh, parity still holds
        rg = [[1, 2, 3, 4], [5, 6], [7, 8, 9]]
        c = mcts_decode_batch(CFG, params, rg, 1, dcfg)
        u = mcts_decode_batch(CFG, params, rg, 1,
                              dataclasses.replace(dcfg, cached=False))
        assert c == u, (c, u)
        print("OK")
    """)
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "JAX_PLATFORMS": "cpu",
             "PATH": "/usr/bin:/bin:/usr/local/bin", "HOME": "/root"})
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "OK" in r.stdout
