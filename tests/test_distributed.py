"""Distributed-mechanism tests on 8 forced host devices (subprocess so the
main test session keeps 1 device)."""
import subprocess
import sys
import textwrap

import pytest


def _run(code: str):
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PATH": "/usr/bin:/bin:/usr/local/bin", "HOME": "/root"})
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    return r.stdout


def test_ring_collectives():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.parallel.compat import make_mesh
        from jax.sharding import PartitionSpec as P
        from repro.parallel.compat import shard_map
        from repro.parallel.collectives import ring_all_gather, ring_reduce_scatter
        mesh = make_mesh((4,), ("data",))
        x = jnp.arange(32.0).reshape(32, 1)
        ag = jax.jit(lambda v: shard_map(lambda u: ring_all_gather(u, "data"),
            mesh=mesh, in_specs=P("data"), out_specs=P(None, None), check_vma=False)(v))(x)
        assert (ag[:32] == x).all()
        rs = jax.jit(lambda v: shard_map(lambda u: ring_reduce_scatter(u, "data"),
            mesh=mesh, in_specs=P(None), out_specs=P("data"), check_vma=False)(v))(x)
        assert jnp.allclose(rs, x * 4)
        print("OK")
    """)
    assert "OK" in out


def test_compressed_psum_and_ef():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.parallel.compat import make_mesh
        from jax.sharding import PartitionSpec as P
        from repro.parallel.compat import shard_map
        from repro.parallel.collectives import compressed_psum, make_ef_compressor
        mesh = make_mesh((4,), ("data",))
        y = jax.random.normal(jax.random.key(0), (1024,))
        ps = jax.jit(lambda v: shard_map(lambda u: compressed_psum(u, "data"),
            mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False)(v))(y)
        rel = float(jnp.abs(ps - 4*y).max() / jnp.abs(4*y).max())
        assert rel < 0.02, rel
        grads = {"w": jax.random.normal(jax.random.key(1), (512,))}
        comp, init_err = make_ef_compressor(grads, mesh)
        err = init_err(grads)
        red, new_err = comp(grads["w"], err["w"], P())
        # error feedback: err + dequant == corrected exactly
        assert float(jnp.abs(new_err).max()) > 0
        print("OK")
    """)
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.parallel.compat import make_mesh
        from repro.parallel.pipeline import pipeline_forward
        mesh = make_mesh((4,), ("stage",))
        L, B, D = 8, 8, 16
        Ws = jax.random.normal(jax.random.key(2), (L, D, D)) * 0.2
        x = jax.random.normal(jax.random.key(3), (B, D))
        blk = lambda w, h: jnp.tanh(h @ w)
        seq = x
        for i in range(L): seq = blk(Ws[i], seq)
        pp = jax.jit(lambda w, v: pipeline_forward(blk, w, v, mesh, n_micro=4))(Ws, x)
        assert float(jnp.abs(pp - seq).max()) < 1e-5
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_runs():
    """Real sharded execution (not just lowering) of a smoke train step."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.parallel.compat import make_mesh
        from repro.configs import get_smoke_config
        from repro.models.base import get_family, abstract_params
        from repro.launch.steps import make_train_step
        from repro.launch.mesh import make_host_mesh
        from repro.parallel.sharding import make_shardings
        from repro.optim import adamw
        from repro.optim.schedules import constant
        import numpy as np
        cfg = get_smoke_config("qwen2-0.5b").replace(dtype="float32")
        fam = get_family(cfg)
        mesh = make_mesh((2, 4), ("data", "model"))
        params = fam.init(cfg, jax.random.key(0))
        pshard = make_shardings(fam.param_axes(cfg), params, mesh)
        params = jax.device_put(params, pshard)
        opt = adamw()
        opt_state = opt.init(params)
        step = make_train_step(cfg, opt, constant(1e-3))
        toks = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        with mesh:
            p2, o2, m = jax.jit(step)(params, opt_state, batch)
        loss_sharded = float(m["loss"])
        # compare against single-device result
        params_local = jax.device_get(params)
        p3, o3, m3 = jax.jit(step)(params_local, opt.init(params_local), batch)
        assert abs(loss_sharded - float(m3["loss"])) < 1e-4
        print("OK", loss_sharded)
    """)
    assert "OK" in out
