import os

# Tests run on the single real CPU device (NOT the 512-device dry-run env);
# a couple of distributed tests spawn their own device count via subprocess.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
