"""Serving on the unified search API: batched MCTS decode and the engine's
decode="mcts" mode (one batched multi-root search per emitted token)."""
import jax
import numpy as np
import pytest

from repro.models.base import ModelConfig, get_family
from repro.serving import (EngineConfig, MCTSDecodeConfig, Request,
                           ServingEngine, mcts_decode, mcts_decode_batch)

CFG = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                  dtype="float32", ce_chunk=8, remat=False)
DCFG = MCTSDecodeConfig(num_actions=3, budget=6, lanes=2, search_depth=2,
                        rollout_len=1)


@pytest.fixture(scope="module")
def params():
    return get_family(CFG).init(CFG, jax.random.key(0))


def test_mcts_decode_emits_tokens(params):
    toks = mcts_decode(CFG, params, np.array([1, 2, 3], np.int32), 2, DCFG)
    assert len(toks) == 2
    assert all(0 <= t < CFG.vocab_size for t in toks)


def test_mcts_decode_batch_shapes(params):
    prompts = np.array([[1, 2, 3], [4, 5, 6]], np.int32)
    out = mcts_decode_batch(CFG, params, prompts, 2, DCFG)
    assert len(out) == 2 and all(len(o) == 2 for o in out)
    assert all(0 <= t < CFG.vocab_size for o in out for t in o)


def test_mcts_decode_batch_ragged_prompts(params):
    """Ragged prompt lists share one padded buffer; true lengths ride along
    as prompt_len, and a padded copy of a request decodes identically."""
    out = mcts_decode_batch(CFG, params, [[1, 2, 3], [4, 5], [6]], 2, DCFG)
    assert len(out) == 3 and all(len(o) == 2 for o in out)
    assert all(0 <= t < CFG.vocab_size for o in out for t in o)
    solo = mcts_decode_batch(CFG, params, [[4, 5]], 2, DCFG)
    assert solo[0] == out[1]


def test_mcts_decode_batch_accepts_device_arrays(params):
    """2-D jax arrays work exactly like the equivalent numpy prompts."""
    import jax.numpy as jnp
    p = np.array([[1, 2, 3], [4, 5, 6]], np.int32)
    assert (mcts_decode_batch(CFG, params, jnp.asarray(p), 2, DCFG)
            == mcts_decode_batch(CFG, params, p, 2, DCFG))


def test_mcts_decode_batch_rejects_flat_prompts(params):
    with pytest.raises(ValueError, match="B, plen"):
        mcts_decode_batch(CFG, params, np.array([1, 2, 3], np.int32), 1, DCFG)
    with pytest.raises(ValueError, match="1-D"):
        mcts_decode_batch(CFG, params, [1, 2, 3], 1, DCFG)


def test_mcts_decode_batch_rejects_empty_prompts(params):
    """Zero-length prompts have no next-token position — fail loudly rather
    than emit garbage (cached and uncached would diverge silently)."""
    with pytest.raises(ValueError, match="at least one token"):
        mcts_decode_batch(CFG, params, [[1, 2, 3], []], 1, DCFG)
    with pytest.raises(ValueError, match="at least one request"):
        mcts_decode_batch(CFG, params, [], 1, DCFG)


def test_engine_mcts_mode_drains_mixed_lengths(params):
    eng = ServingEngine(CFG, params, EngineConfig(
        max_batch=2, max_seq=16, decode="mcts", mcts=DCFG))
    eng.submit(Request(uid=0, prompt=np.array([1, 2, 3], np.int32),
                       max_new_tokens=2))
    eng.submit(Request(uid=1, prompt=np.array([4, 5], np.int32),
                       max_new_tokens=3))
    res = eng.run_until_drained()
    assert res["tokens"] == 5
    assert len(eng.slots[0].out_tokens) == 2
    assert len(eng.slots[1].out_tokens) == 3
    assert all(s.done for s in eng.slots)


def test_engine_rejects_unknown_decode_mode(params):
    with pytest.raises(ValueError, match="decode mode"):
        ServingEngine(CFG, params, EngineConfig(max_batch=1, decode="beam"))


def test_engine_rejects_oversized_prompt(params):
    eng = ServingEngine(CFG, params, EngineConfig(
        max_batch=1, max_seq=8, decode="mcts", mcts=DCFG))
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(Request(uid=0, prompt=np.arange(9, dtype=np.int32) % 60,
                           max_new_tokens=1))


def test_engine_zero_max_new_tokens_finishes_without_emitting(params):
    for mode in ("greedy", "mcts"):
        eng = ServingEngine(CFG, params, EngineConfig(
            max_batch=1, max_seq=16, decode=mode, mcts=DCFG))
        eng.submit(Request(uid=0, prompt=np.array([1, 2], np.int32),
                           max_new_tokens=0))
        eng.run_until_drained()
        assert eng.slots[0].done
        assert eng.slots[0].out_tokens == []


def test_engine_greedy_clamps_decode_at_kv_capacity(params):
    """Greedy slots stop before decode steps would scatter KV entries past
    max_seq (prompt fills the cache -> only the prefill token is emitted)."""
    eng = ServingEngine(CFG, params, EngineConfig(max_batch=1, max_seq=8))
    eng.submit(Request(uid=0, prompt=np.arange(8, dtype=np.int32) % 60 + 1,
                       max_new_tokens=4))
    eng.run_until_drained()
    req = eng.slots[0]
    assert req.done
    assert len(req.out_tokens) == 1


def test_engine_mcts_finishes_at_sequence_capacity(params):
    """A request whose decode would overrun max_seq is finished at capacity
    instead of emitting from a frozen prefix forever."""
    eng = ServingEngine(CFG, params, EngineConfig(
        max_batch=1, max_seq=6, decode="mcts", mcts=DCFG))
    eng.submit(Request(uid=0, prompt=np.array([1, 2, 3, 4], np.int32),
                       max_new_tokens=10))
    eng.run_until_drained()
    req = eng.slots[0]
    assert req.done
    # 2 tokens extend the prefix to max_seq, a 3rd is emitted from the full
    # prefix and the request is closed there
    assert len(req.out_tokens) == 3


# -- request lifecycle (scheduler + stats, DESIGN.md §12) --------------------

def _eos_stub(eng, tok):
    """Replace the batched searcher with one that always emits ``tok``."""
    import jax.numpy as jnp
    b = eng.ecfg.max_batch
    eng._mcts_search = lambda buf, lens, rng: jnp.full((b,), tok, jnp.int32)


def test_engine_eos_mid_budget_frees_slot_same_step(params):
    """EOS mid-budget must retire the slot AND refill it within the same
    engine step — the replacement is live before the next step() call."""
    eng = ServingEngine(CFG, params, EngineConfig(
        max_batch=1, max_seq=16, eos_token=7, decode="mcts", mcts=DCFG))
    _eos_stub(eng, 7)
    eng.submit(Request(uid=0, prompt=np.array([1, 2], np.int32),
                       max_new_tokens=5))
    eng.submit(Request(uid=1, prompt=np.array([3, 4], np.int32),
                       max_new_tokens=5))
    emitted = eng.step()
    assert emitted == 1
    # uid0 finished well under budget...
    assert eng.sched.request(0).uid == 1 or eng.slots[0].uid == 1
    # ...and uid1 was admitted into the freed slot within the same step
    assert eng.sched.live() == [0]
    assert eng.sched.request(0).uid == 1
    assert eng.step() == 1
    assert all(s.done for s in eng.slots)
    assert eng.stats.requests[0].tokens == 1     # stopped at EOS, not budget


def test_engine_populates_lifecycle_timestamps(params):
    eng = ServingEngine(CFG, params, EngineConfig(
        max_batch=2, max_seq=16, decode="mcts", mcts=DCFG))
    r0 = Request(uid=0, prompt=np.array([1, 2, 3], np.int32),
                 max_new_tokens=2)
    eng.submit(r0)
    out = eng.run_until_drained()
    assert r0.enqueue_t > 0.0
    assert r0.finish_t >= r0.enqueue_t
    s = out["requests"][0]
    assert s["done"] and s["tokens"] == 2
    for k in ("queue_wait", "ttft", "latency"):
        assert s[k] is not None and s[k] >= 0.0
    assert out["latency_p95"] >= out["latency_p50"] > 0.0
    snap = out["stats"]
    assert snap["serving/requests_finished"] == 1.0
    assert snap["serving/tokens"] == 2.0
    assert snap["serving/searches"] >= 2.0


def test_engine_greedy_records_stats(params):
    eng = ServingEngine(CFG, params, EngineConfig(max_batch=2, max_seq=16))
    eng.submit(Request(uid=0, prompt=np.array([1, 2], np.int32),
                       max_new_tokens=3))
    out = eng.run_until_drained()
    assert out["tokens"] >= 2                 # decode steps (prefill extra)
    assert out["requests"][0]["tokens"] == 3  # prefill token + decode steps
    assert out["requests"][0]["done"]
    assert out["stats"]["serving/requests_finished"] == 1.0


def test_engine_preemption_roundtrip_keeps_committed_tokens(params):
    """A higher-priority arrival evicts the live request; the victim
    resumes later with its committed tokens intact and finishes its full
    budget (prompt + committed becomes the readmission prefix)."""
    eng = ServingEngine(CFG, params, EngineConfig(
        max_batch=1, max_seq=32, decode="mcts", mcts=DCFG))
    eng.submit(Request(uid=0, prompt=np.array([1, 2, 3], np.int32),
                       max_new_tokens=4, priority=0))
    assert eng.step() == 1                    # uid0 commits one token
    first = list(eng.slots[0].out_tokens)
    eng.submit(Request(uid=1, prompt=np.array([4, 5], np.int32),
                       max_new_tokens=2, priority=5))
    out = eng.run_until_drained()
    reqs = out["requests"]
    assert reqs[0]["done"] and reqs[1]["done"]
    assert reqs[0]["preemptions"] == 1
    assert reqs[0]["tokens"] == 4             # full budget despite eviction
    assert reqs[1]["tokens"] == 2
    victim = next(s for s in eng.slots if s and s.uid == 0)
    assert victim.out_tokens[: len(first)] == first
    assert out["stats"]["serving/preemptions"] == 1.0


@pytest.mark.parametrize("policy", ("fcfs", "spf"))
def test_engine_admission_policy_wired(params, policy):
    eng = ServingEngine(CFG, params, EngineConfig(
        max_batch=1, max_seq=16, decode="mcts", policy=policy, mcts=DCFG))
    eng.submit(Request(uid=0, prompt=np.array([1, 2, 3, 4], np.int32),
                       max_new_tokens=2))
    eng.submit(Request(uid=1, prompt=np.array([5], np.int32),
                       max_new_tokens=2))
    eng.step()
    first_uid = next(s.uid for s in eng.slots if s)
    assert first_uid == (1 if policy == "spf" else 0)
    eng.run_until_drained()
    assert eng.stats.finished == 2


def test_engine_reuse_mode_drains(params):
    """KV splice + subtree reuse through the full engine lifecycle: the
    stateful carry survives admissions, refills and completion."""
    dcfg = MCTSDecodeConfig(num_actions=3, budget=6, lanes=2, search_depth=2,
                            rollout_len=1, kv_splice=True, tree_reuse=True)
    eng = ServingEngine(CFG, params, EngineConfig(
        max_batch=2, max_seq=16, decode="mcts", mcts=dcfg, mesh=False))
    for uid, (plen, n) in enumerate(((3, 2), (2, 3), (4, 2))):
        eng.submit(Request(uid=uid, prompt=np.arange(1, plen + 1,
                                                     dtype=np.int32),
                           max_new_tokens=n))
    out = eng.run_until_drained()
    assert out["tokens"] == 7
    assert all(r["done"] for r in out["requests"].values())
    assert eng._carry is not None
