"""Per-architecture smoke tests (deliverable f): reduced same-family configs,
one forward/train step on CPU, asserting output shapes + no NaNs, plus
prefill/decode consistency for every family with an inference path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.data import DataConfig, synthetic_batch
from repro.launch.steps import make_train_step
from repro.models.base import count_params, get_family
from repro.optim import adamw
from repro.optim.schedules import constant

B, S = 2, 16


def _batch(cfg):
    d = DataConfig(seed=0, batch_size=B, seq_len=S)
    b = synthetic_batch(cfg, d, step=0)
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    fam = get_family(cfg)
    params = fam.init(cfg, jax.random.key(0))
    assert count_params(params) > 0
    batch = _batch(cfg)
    opt = adamw()
    step = jax.jit(make_train_step(cfg, opt, constant(1e-3)))
    new_params, opt_state, metrics = step(params, opt.init(params), batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(new_params), jax.tree_util.tree_leaves(params)))
    assert delta > 0
    # no NaNs anywhere in the updated state
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "whisper-base"
                                  and a != "internvl2-2b"])
def test_prefill_decode_consistency(arch):
    """decode(prefill(prompt)) logits == full-forward logits at high capacity."""
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        cfg = cfg.replace(moe_capacity=100.0)   # no token dropping for parity
    fam = get_family(cfg)
    params = fam.init(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, S), 1, cfg.vocab_size)
    cache = fam.init_cache(cfg, B, S + 4)
    lp, cache = fam.prefill(cfg, params, toks, cache)
    full = fam.logits_fn(cfg, params, toks)
    np.testing.assert_allclose(np.asarray(lp[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-4, rtol=2e-4)
    nxt = jnp.argmax(lp[:, 0], -1)[:, None].astype(jnp.int32)
    ld, cache = fam.decode_step(cfg, params, cache, nxt)
    full2 = fam.logits_fn(cfg, params, jnp.concatenate([toks, nxt], 1))
    np.testing.assert_allclose(np.asarray(ld[:, 0]), np.asarray(full2[:, -1]),
                               atol=2e-4, rtol=2e-4)


def test_whisper_prefill_decode():
    cfg = get_smoke_config("whisper-base")
    fam = get_family(cfg)
    params = fam.init(cfg, jax.random.key(0))
    frames = jax.random.normal(jax.random.key(2), (B, cfg.enc_seq, cfg.d_model))
    toks = jax.random.randint(jax.random.key(1), (B, S), 1, cfg.vocab_size)
    batch = {"frames": frames, "tokens": toks}
    cache = fam.init_cache(cfg, B, S + 4)
    lp, cache = fam.prefill(cfg, params, batch, cache)
    full = fam.logits_fn(cfg, params, toks, frames)
    np.testing.assert_allclose(np.asarray(lp[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-4, rtol=2e-4)
    nxt = jnp.argmax(lp[:, 0], -1)[:, None].astype(jnp.int32)
    ld, _ = fam.decode_step(cfg, params, cache, nxt)
    full2 = fam.logits_fn(cfg, params, jnp.concatenate([toks, nxt], 1), frames)
    np.testing.assert_allclose(np.asarray(ld[:, 0]), np.asarray(full2[:, -1]),
                               atol=2e-4, rtol=2e-4)


def test_vlm_multimodal_forward():
    cfg = get_smoke_config("internvl2-2b")
    fam = get_family(cfg)
    params = fam.init(cfg, jax.random.key(0))
    patches = jax.random.normal(jax.random.key(3), (B, cfg.n_patches, cfg.frontend_dim))
    toks = jax.random.randint(jax.random.key(1), (B, S), 1, cfg.vocab_size)
    logits = fam.multimodal_logits(cfg, params, patches, toks)
    assert logits.shape == (B, cfg.n_patches + S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_shapes(arch):
    """Full configs carry the exact assigned dims (abstract only, no alloc)."""
    from repro.models.base import abstract_params
    cfg = get_config(arch)
    n = count_params(abstract_params(cfg))
    expected = {
        "deepseek-v2-lite-16b": (14e9, 17e9),
        "grok-1-314b": (300e9, 330e9),
        "smollm-135m": (120e6, 145e6),
        "qwen2-0.5b": (480e6, 520e6),
        "minicpm-2b": (2.4e9, 3.0e9),
        "stablelm-3b": (2.6e9, 3.1e9),
        "whisper-base": (85e6, 110e6),
        "rwkv6-1.6b": (1.5e9, 1.8e9),
        "zamba2-1.2b": (1.1e9, 1.5e9),
        "internvl2-2b": (1.7e9, 2.1e9),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: {n:,} params"
