
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Dev tool: rank collective ops by loop-aware link bytes for one cell."""
import re, sys
from collections import defaultdict
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_analysis as H


def main(arch, shape, topk=12):
    mesh = make_production_mesh()
    c = lower_cell(arch, shape, mesh)[0].compile()
    t = c.as_text()
    comps = H._parse_computations(t)
    syms = {cn: {i.name: i.rtype for i in ins} for cn, ins in comps.items()}
    # find trips per computation by walking whiles from entry
    entry = None
    for line in t.splitlines():
        if line.startswith("ENTRY"):
            m = H._COMP_HEAD_RE.match(line.replace("ENTRY ", "").strip())
            entry = m.group(1) if m else None
            break
    mult = defaultdict(lambda: 0.0)
    mult[entry] = 1.0

    def walk(cn, m):
        for ins in comps.get(cn, []):
            if ins.op == "while":
                mm = H._COND_BODY_RE.search(ins.rest)
                if mm:
                    trips = H._trip_count(comps.get(mm.group(1), []))
                    mult[mm.group(2)] += m * trips
                    walk(mm.group(2), m * trips)
            elif ins.op == "call":
                mm = H._TO_APPLY_RE.search(ins.rest)
                if mm:
                    mult[mm.group(1)] += m
                    walk(mm.group(1), m)
    walk(entry, 1.0)

    rows = []
    for cn, ins_list in comps.items():
        m = mult.get(cn, 0.0)
        if m <= 0:
            continue
        for ins in ins_list:
            kind = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if kind not in H.COLLECTIVE_OPS:
                continue
            size = H._shape_bytes(ins.rtype)
            g = H._group_size(ins.rest, 256)
            ring = (g - 1) / g if g > 1 else 0.0
            link = {"all-reduce": 2 * size * ring, "all-gather": size * ring,
                    "reduce-scatter": size * g * ring, "all-to-all": size * ring,
                    "collective-permute": size}[kind]
            meta = re.search(r'op_name="([^"]+)"', ins.rest)
            rows.append((link * m, kind, ins.rtype[:38], m,
                         (meta.group(1) if meta else "")[-90:]))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"{arch} {shape}: total link bytes/dev = {total/1e9:.1f} GB")
    for link, kind, shape_, m, meta in rows[:topk]:
        print(f"  {link/1e9:8.2f}GB x{m:5.0f} {kind:18s} {shape_:40s} {meta}")


if __name__ == "__main__":
    main(sys.argv[1], sys.argv[2], int(sys.argv[3]) if len(sys.argv) > 3 else 12)
