import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Dev tool: top-K largest HLO buffers + op_name for one dry-run cell."""
import re
import sys

from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh

DT = {"bf16": 2, "f32": 4, "s32": 4, "u32": 4, "pred": 1, "f16": 2, "s8": 1}


def main(arch, shape, topk=8):
    mesh = make_production_mesh()
    c = lower_cell(arch, shape, mesh)[0].compile()
    t = c.as_text()
    m_an = c.memory_analysis()
    print(f"{arch} {shape}: temp={m_an.temp_size_in_bytes/1e9:.2f}GB "
          f"arg={m_an.argument_size_in_bytes/1e9:.2f}GB")
    seen = {}
    for m in re.finditer(r"%(\S+) = (\w+)\[([\d,]+)\][^ ]* ([\w\-]+)\(", t):
        name, dt, dims, op = m.groups()
        if dt not in DT:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        sz = n * DT[dt]
        key = f"{dt}[{dims}]"
        if sz > 0.2e9:
            seen.setdefault(key, [sz, set(), name])[1].add(op)
    rows = sorted(seen.items(), key=lambda kv: -kv[1][0])[:topk]
    for k, (sz, ops, name) in rows:
        meta = ""
        for line in t.splitlines():
            if k in line and "op_name" in line:
                mm = re.search(r'op_name="([^"]+)"', line)
                if mm:
                    meta = mm.group(1)[-110:]
                    break
        print(f"  {sz/1e9:7.2f}GB {k:42s} {meta}")


if __name__ == "__main__":
    main(sys.argv[1], sys.argv[2], int(sys.argv[3]) if len(sys.argv) > 3 else 8)
