"""Execute every fenced ```python block in README.md — the CI docs job.

Each block runs in its own namespace with assertions live, so a quickstart
snippet that drifts from the real API fails the build instead of rotting.
Blocks whose info string is anything other than ``python`` (bash, text, …)
are skipped.  Usage:

    PYTHONPATH=src python tools/run_readme_blocks.py [README.md ...]
"""
from __future__ import annotations

import pathlib
import re
import sys

FENCE = re.compile(r"^```(\w*)\s*$")


def extract_python_blocks(text: str):
    """Yield (start_line, source) for each ```python fenced block."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if m:
            lang, start = m.group(1), i + 1
            body = []
            i += 1
            while i < len(lines) and not FENCE.match(lines[i]):
                body.append(lines[i])
                i += 1
            if i >= len(lines):
                raise SystemExit(f"unclosed code fence at line {start}")
            if lang == "python":
                yield start + 1, "\n".join(body)
        i += 1


def main(paths) -> int:
    failures = 0
    for path in paths:
        text = pathlib.Path(path).read_text()
        blocks = list(extract_python_blocks(text))
        if not blocks:
            print(f"{path}: no python blocks found", file=sys.stderr)
            failures += 1
            continue
        for lineno, src in blocks:
            label = f"{path}:{lineno}"
            try:
                code = compile(src, label, "exec")
                exec(code, {"__name__": f"readme_block_{lineno}"})
                print(f"ok   {label}")
            except Exception as e:  # noqa: BLE001 — report and keep going
                failures += 1
                print(f"FAIL {label}: {type(e).__name__}: {e}",
                      file=sys.stderr)
    return failures


if __name__ == "__main__":
    files = sys.argv[1:] or ["README.md"]
    raise SystemExit(1 if main(files) else 0)
