import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""§Perf D3: deepseek train_4k with explicit shard_map expert parallelism.

Reproduces the EXPERIMENTS.md D3 measurement: moe_impl='ep' + rules
{experts->model, no FSDP}. Compare against the default-sweep D2 record.
"""
import time

from repro.launch import hlo_analysis
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh
from repro.parallel.sharding import DEFAULT_RULES
import repro.configs.deepseek_v2_lite_16b as DS


def main():
    rules = dict(DEFAULT_RULES)
    rules["experts"] = (("model",),)
    rules["embed"] = ()
    DS.CONFIG = DS.CONFIG.replace(moe_impl="ep")
    mesh = make_production_mesh()
    t0 = time.time()
    lowered, _ = lower_cell("deepseek-v2-lite-16b", "train_4k", mesh, rules)
    c = lowered.compile()
    m = c.memory_analysis()
    costs = hlo_analysis.analyze_module(c.as_text(), 256)
    print(f"compile {time.time()-t0:.0f}s args {m.argument_size_in_bytes/1e9:.2f}GB "
          f"temp {m.temp_size_in_bytes/1e9:.1f}GB flops/dev {costs.flops:.3e} "
          f"link/dev {costs.link_bytes/1e9:.1f}GB")
    print("schedule:", hlo_analysis.schedule_summary(costs.collectives))


if __name__ == "__main__":
    main()
