"""API-surface checker: ``repro.search`` is the only place allowed to grow
public search entry points, and ``repro.core.arena`` is the only place
allowed to subscript tree planes by string key.

Fails (exit 1) if

* any module under ``src/repro`` *outside* ``repro/search`` defines a new
  module-level public ``run_*`` function.  The non-search ``run_*`` helpers
  that predate this policy are pinned in ``ALLOWED``; removing one is fine,
  adding one is not — add new strategies via
  ``repro.search.register_strategy`` instead (DESIGN.md §8); or
* any module under ``src/repro`` outside ``PLANE_ALLOWED`` subscripts a
  tree plane dict-style (``tree["visits"]`` etc.).  The tree is a typed
  ``TreeArena`` now (DESIGN.md §14) — use attribute access
  (``tree.visits``) / ``tree.replace(...)``.  The ``__getitem__`` shim
  exists only for out-of-repo callers and warns ``DeprecationWarning``.

Usage:  python tools/api_surface.py [--root PATH]
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

# module path (relative to src/) -> permitted module-level run_* names
# (the deprecated core.run_* shims were removed after their grace period;
# only non-search helpers that happen to match the pattern remain)
ALLOWED = {
    "repro/runtime/ft.py": {"run_with_restarts"},
    "repro/launch/dryrun.py": {"run_cell"},
}

DEF_RE = re.compile(r"^def (run_\w+)\s*\(", re.MULTILINE)

# TreeArena plane names: dict-style subscripts on these are banned in src/
# outside the arena itself (the shim's own definition lives there).  Names
# the stage buffers / serving carries legitimately use as dict keys
# ("value", "state", "action", ...) are deliberately NOT policed — the set
# below is unambiguous to the arena.
PLANES = ("visits", "vloss", "unobs", "children", "next_free", "free_list",
          "free_top", "terminal", "prior")
# arena.py/tree.py own the shim; search_wave/ops.py stages planes into a
# plain dict of kernel operands (2-D views, not the tree) keyed by plane.
PLANE_ALLOWED = {"repro/core/arena.py", "repro/core/tree.py",
                 "repro/kernels/search_wave/ops.py"}
# a dict literal key ({"prior": ...}) or .get() is not a subscript — the
# regex targets ``<expr>["plane"]`` via the closing-bracket/name prefix.
PLANE_CTX_RE = re.compile(
    r"""[\w\)\]]\s*\[\s*['"](%s)['"]\s*\]""" % "|".join(PLANES))

# The WU-UCT unobserved-count plane (DESIGN.md §15) is core-private
# bookkeeping: its vl_mode pairing with ``vloss`` is owned by
# ``core.stages.infl_plane`` / ``with_infl``.  Indexing ``.unobs`` directly
# (subscript or ``.at[...]`` update) outside ``repro/core/`` bypasses that
# contract — kernels receive the active plane as a staged operand instead.
UNOBS_DIRECT_RE = re.compile(r"\.unobs\s*(?:\[|\.\s*at\b)")
UNOBS_ALLOWED_PREFIX = "repro/core/"


def check(src_root: pathlib.Path) -> list:
    violations = []
    for path in sorted(src_root.rglob("*.py")):
        rel = path.relative_to(src_root).as_posix()
        text = path.read_text()
        if not rel.startswith("repro/search/"):
            found = set(DEF_RE.findall(text))
            extra = found - ALLOWED.get(rel, set())
            violations.extend(
                (rel, f"new public search entry point {name!r} — register "
                      "a strategy in repro.search instead")
                for name in sorted(extra))
        if rel not in PLANE_ALLOWED:
            for i, line in enumerate(text.splitlines(), 1):
                m = PLANE_CTX_RE.search(line)
                if m:
                    violations.append(
                        (rel, f"line {i}: dict-style tree plane access "
                              f'[{m.group(1)!r}] — the tree is a typed '
                              "TreeArena; use attribute access / .replace()"))
        if not rel.startswith(UNOBS_ALLOWED_PREFIX):
            for i, line in enumerate(text.splitlines(), 1):
                if UNOBS_DIRECT_RE.search(line):
                    violations.append(
                        (rel, f"line {i}: direct '.unobs' plane indexing "
                              "outside repro/core/ — go through "
                              "stages.infl_plane / with_infl"))
    return violations


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=None,
                    help="repo root (default: this script's parent's parent)")
    args = ap.parse_args(argv)
    root = pathlib.Path(args.root) if args.root else \
        pathlib.Path(__file__).resolve().parent.parent
    violations = check(root / "src")
    for rel, msg in violations:
        print(f"api_surface: {rel}: {msg}", file=sys.stderr)
    if violations:
        return 1
    print("api_surface: OK — repro.search is the only public search API; "
          "tree planes are attribute-only outside core/arena.py")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
