"""API-surface checker: ``repro.search`` is the only place allowed to grow
public search entry points.

Fails (exit 1) if any module under ``src/repro`` *outside* ``repro/search``
defines a new module-level public ``run_*`` function.  The non-search
``run_*`` helpers that predate this policy are pinned in ``ALLOWED``;
removing one is fine, adding one is not — add new strategies via
``repro.search.register_strategy`` instead (DESIGN.md §8).

Usage:  python tools/api_surface.py [--root PATH]
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

# module path (relative to src/) -> permitted module-level run_* names
# (the deprecated core.run_* shims were removed after their grace period;
# only non-search helpers that happen to match the pattern remain)
ALLOWED = {
    "repro/runtime/ft.py": {"run_with_restarts"},
    "repro/launch/dryrun.py": {"run_cell"},
}

DEF_RE = re.compile(r"^def (run_\w+)\s*\(", re.MULTILINE)


def check(src_root: pathlib.Path) -> list:
    violations = []
    for path in sorted(src_root.rglob("*.py")):
        rel = path.relative_to(src_root).as_posix()
        if rel.startswith("repro/search/"):
            continue
        found = set(DEF_RE.findall(path.read_text()))
        extra = found - ALLOWED.get(rel, set())
        violations.extend((rel, name) for name in sorted(extra))
    return violations


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=None,
                    help="repo root (default: this script's parent's parent)")
    args = ap.parse_args(argv)
    root = pathlib.Path(args.root) if args.root else \
        pathlib.Path(__file__).resolve().parent.parent
    violations = check(root / "src")
    for rel, name in violations:
        print(f"api_surface: {rel}: new public search entry point {name!r} — "
              "register a strategy in repro.search instead", file=sys.stderr)
    if violations:
        return 1
    print("api_surface: OK — repro.search is the only public search API")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
